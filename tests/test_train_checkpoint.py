"""Training loop behaviour: loss decreases, over-decomposition equivalence,
checkpoint/restart (fault tolerance), data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.models import build_smoke
from repro.train import (TrainConfig, abstract_train_state, init_train_state,
                         make_train_step)

KEY = jax.random.PRNGKey(0)


def _setup(arch="yi_9b", od=1):
    from repro.train import AdamWConfig
    cfg = get_smoke_config(arch)
    m = build_smoke(cfg)
    state = init_train_state(m, KEY)
    opt = AdamWConfig(lr_peak=2e-3, warmup_steps=5, total_steps=500,
                      weight_decay=0.0)
    step = jax.jit(make_train_step(m, TrainConfig(opt=opt,
                                                  over_decompose=od)))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8, seed=3))
    return m, state, step, data


def test_loss_decreases_over_steps():
    m, state, step, data = _setup()
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_over_decomposition_matches_monolithic():
    """od=4 microbatching gives (nearly) the same update as od=1 — the
    over-decomposition transform must not change semantics."""
    m, state1, step1, data = _setup(od=1)
    _, state4, step4, _ = _setup(od=4)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    s1, m1 = step1(state1, batch)
    s4, m4 = step4(state4, batch)
    assert abs(float(m1["ce"]) - float(m4["ce"])) < 1e-3
    deltas = [float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                    b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(s1.params),
                              jax.tree.leaves(s4.params))]
    assert max(deltas) < 5e-3


def test_checkpoint_restart_bitexact(tmp_path):
    """Kill-and-restore: resumed training matches uninterrupted training —
    the core fault-tolerance contract."""
    m, state, step, data = _setup()
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)

    # run 6 steps, checkpoint at 3
    s = state
    for i in range(3):
        s, _ = step(s, {k: jnp.asarray(v) for k, v in data.batch(i).items()})
    ck.save(3, s, block=True)
    s_cont = s
    for i in range(3, 6):
        s_cont, _ = step(
            s_cont, {k: jnp.asarray(v) for k, v in data.batch(i).items()})

    # "crash": restore from step 3 and replay — stateless data pipeline
    abs_state = abstract_train_state(m)
    s_rest = ck.restore(3, abs_state)
    for i in range(3, 6):
        s_rest, _ = step(
            s_rest, {k: jnp.asarray(v) for k, v in data.batch(i).items()})

    for a, b in zip(jax.tree.leaves(s_cont.params),
                    jax.tree.leaves(s_rest.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation_and_torn_write(tmp_path):
    m, state, step, data = _setup()
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s_id in (1, 2, 3):
        ck.save(s_id, state, block=True)
    assert ck.all_steps() == [2, 3]
    # torn checkpoint (no COMMIT) is ignored
    os.makedirs(tmp_path / "step_9")
    assert ck.latest_step() == 3


def test_data_pipeline_determinism_and_host_sharding():
    base = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=7)
    a = SyntheticLM(base).batch(5)
    b = SyntheticLM(base).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=8,
                                seed=7, host_index=0, host_count=2))
    h1 = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=8,
                                seed=7, host_index=1, host_count=2))
    b0, b1 = h0.batch(0), h1.batch(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_next_tokens():
    d = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=4))
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
