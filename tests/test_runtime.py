"""Heterogeneous tasking runtime: dependency, coherence, scheduler, memory
invariants — including hypothesis property tests on random task DAGs."""
import threading
import time

import numpy as np
import pytest

try:        # hypothesis is optional: only the DAG property test needs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (HOST, HeteroTask, Runtime,  # noqa: E402
                        RuntimeConfig, TaskState)


def add_one(x, out):
    return x + 1.0


def scale(x, out):
    return x * 2.0


def combine(a, b, out):
    return a + b


@pytest.fixture()
def rt():
    r = Runtime(RuntimeConfig(memory_capacity=1 << 28))
    yield r
    r.shutdown()


def test_raw_dependency_order(rt):
    """Writer → reader executes in order (RAW)."""
    x = rt.hetero_object(np.zeros((8, 8), np.float32))
    y = rt.hetero_object(shape=(8, 8), dtype=np.float32)
    z = rt.hetero_object(shape=(8, 8), dtype=np.float32)
    rt.run(add_one, [(x, "r"), (y, "w")])       # y = x+1 = 1
    rt.run(scale, [(y, "r"), (z, "w")])         # z = 2y = 2
    rt.barrier()
    np.testing.assert_allclose(z.get(), 2.0)


def test_implicit_chain_is_sequential(rt):
    """N rw tasks on one object must serialize: result is exact."""
    x = rt.hetero_object(np.zeros((4,), np.float32))
    for _ in range(20):
        rt.run(lambda v: v + 1.0, [(x, "rw")])
    rt.barrier()
    np.testing.assert_allclose(x.get(), 20.0)


def test_war_blocks_writer(rt):
    x = rt.hetero_object(np.ones((4,), np.float32))
    y = rt.hetero_object(shape=(4,), dtype=np.float32)
    t_read = rt.run(scale, [(x, "r"), (y, "w")])
    t_write = rt.run(lambda v: v * 0.0, [(x, "rw")])
    rt.barrier()
    # reader saw the pre-write value
    np.testing.assert_allclose(y.get(), 2.0)
    np.testing.assert_allclose(x.get(), 0.0)


def test_explicit_dependency(rt):
    order = []
    lock = threading.Lock()
    a = rt.hetero_object(np.zeros((2,), np.float32))
    b = rt.hetero_object(np.zeros((2,), np.float32))

    def mark(tag):
        def k(v):
            with lock:
                order.append(tag)
            return v
        return k

    t1 = HeteroTask("first")
    t1.arg(a).rw()
    t2 = HeteroTask("second")
    t2.arg(b).rw()
    t2.add_dependency(t1)
    # submit in REVERSE order; explicit dep must still serialize
    rt.submit(t2, mark("second"))
    time.sleep(0.02)
    rt.submit(t1, mark("first"))
    rt.barrier()
    assert order == ["first", "second"]


def test_host_pin_blocks_writer(rt):
    x = rt.hetero_object(np.ones((4,), np.float32))
    fut = x.request_host()
    arr = fut.get(5)
    np.testing.assert_allclose(arr, 1.0)
    x.release()
    rt.run(lambda v: v + 1, [(x, "rw")])
    rt.barrier()
    np.testing.assert_allclose(x.get(), 2.0)


def test_write_invalidates_other_copies(rt):
    x = rt.hetero_object(np.ones((4,), np.float32))
    rt.run(lambda v: v + 1, [(x, "rw")])
    rt.barrier()
    # after a device write, host copy must be refreshed on access
    np.testing.assert_allclose(x.get(), 2.0)
    np.testing.assert_allclose(x.get(), 2.0)


def test_lru_offload_under_pressure():
    """Tiny memory budget forces evictions but never corrupts data."""
    cap = 4 * 64 * 64 * 4 + 128   # ~4 objects of 16KB
    with Runtime(RuntimeConfig(memory_capacity=cap)) as rt:
        objs = [rt.hetero_object(np.full((64, 64), i, np.float32))
                for i in range(10)]
        for o in objs:
            rt.run(lambda v: v + 1, [(o, "rw")])
        rt.barrier()
        for i, o in enumerate(objs):
            np.testing.assert_allclose(o.get(), i + 1)
        assert rt.stats()["evictions"] > 0


@pytest.mark.parametrize("sched", ["fifo", "least_loaded", "locality",
                                   "round_robin"])
def test_all_schedulers_complete(sched):
    with Runtime(RuntimeConfig(scheduler=sched,
                               memory_capacity=1 << 28)) as rt:
        x = rt.hetero_object(np.zeros((16,), np.float32))
        for _ in range(10):
            rt.run(lambda v: v + 1, [(x, "rw")])
        rt.barrier()
        np.testing.assert_allclose(x.get(), 10.0)


if HAVE_HYPOTHESIS:
    _dag_decorators = [
        settings(max_examples=15, deadline=None),
        given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 4),
                                 st.booleans()), min_size=1, max_size=25))]
else:
    _dag_decorators = [pytest.mark.skip(reason="hypothesis not installed")]


def _apply(decorators):
    def wrap(fn):
        for d in reversed(decorators):
            fn = d(fn)
        return fn
    return wrap


@_apply(_dag_decorators)
def test_random_dag_equals_sequential(ops_list=None):
    """Property: any random read/write program gives results identical to
    sequential execution (the paper's correctness guarantee)."""
    with Runtime(RuntimeConfig(memory_capacity=1 << 28)) as rt:
        objs = [rt.hetero_object(np.full((4,), float(i), np.float32))
                for i in range(5)]
        model = [np.full((4,), float(i), np.float32) for i in range(5)]
        for src, dst, extra in ops_list:
            if src == dst:
                rt.run(lambda v: v * 2.0 + 1.0, [(objs[src], "rw")])
                model[src] = model[src] * 2.0 + 1.0
            else:
                # kernel reads a and the CURRENT b (rw), returns a + b
                rt.run(lambda a, b: a + b,
                       [(objs[src], "r"), (objs[dst], "rw")])
                model[dst] = model[src] + model[dst]
        rt.barrier()
        for i in range(5):
            np.testing.assert_allclose(objs[i].get(), model[i], rtol=1e-6)


def test_device_type_targeting(rt):
    """A task targeted at the present device type runs; unknown types have no
    eligible device and stay queued (we only check the positive path)."""
    x = rt.hetero_object(np.ones((4,), np.float32))
    t = rt.run(lambda v: v + 1, [(x, "rw")],
               device_type=rt.devices[0].info.device_type)
    rt.barrier()
    assert t.state == TaskState.DONE
    np.testing.assert_allclose(x.get(), 2.0)


def test_stats_and_staging_pool(rt):
    x = rt.hetero_object(np.ones((32, 32), np.float32))
    for _ in range(3):
        rt.run(lambda v: v + 1, [(x, "rw")])
    rt.barrier()
    s = rt.stats()
    assert s["tasks"] == 3
    assert s["bytes_h2d"] >= x.nbytes
