"""End-to-end elastic fault tolerance: deterministic fault injection,
retry/NACK recovery on the reliability layer, heartbeat-driven failure
detection, live chunk migration, straggler drains, and the diagnostics
attached to stuck-cluster errors."""
import json
import os
import threading
import time
import types

import numpy as np
import pytest

from repro.core import RuntimeConfig
from repro.distributed import (Cluster, ElasticController, ElasticRuntime,
                               FaultInjector, OwnerMap, handler)
from repro.apps.jacobi3d import run_cluster_elastic, run_reference

_got = {}
_lock = threading.Lock()


@handler(name="ft_recv")
def _ft_recv(ctx, obj):
    with _lock:
        _got.setdefault(ctx.message.user["tag"], []).append(
            None if obj is None else np.asarray(obj.get()))


def _wait(pred, timeout=30.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        with _lock:
            if pred():
                return True
        time.sleep(0.005)
    return False


@pytest.fixture(autouse=True)
def _clear_got():
    with _lock:
        _got.clear()
    yield


def _cfg(**kw):
    return RuntimeConfig(memory_capacity=1 << 26, **kw)


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------

def test_fault_injector_deterministic_under_seed():
    """Same seed + same message order → identical fault decisions."""
    msgs = [types.SimpleNamespace(src=i % 2, dst=(i + 1) % 2)
            for i in range(300)]

    def decisions(seed):
        fi = FaultInjector(None, seed=seed)
        fi.set_link(0, 1, drop=0.3, dup=0.2)
        fi.set_link(1, 0, drop=0.1)
        return [fi.intercept(m) for m in msgs]

    assert decisions(7) == decisions(7)
    assert decisions(7) != decisions(8)


def test_fault_injector_kill_and_freeze_semantics():
    fi = FaultInjector(None, seed=0)
    fi.kill_rank(1)
    drop, delay, dup = fi.intercept(types.SimpleNamespace(src=0, dst=1))
    assert drop and not dup
    # both directions die: a killed rank is gone to the whole network
    drop, _, _ = fi.intercept(types.SimpleNamespace(src=1, dst=0))
    assert drop
    fi.revive_rank(1)
    drop, _, _ = fi.intercept(types.SimpleNamespace(src=0, dst=1))
    assert not drop
    fi.freeze_rank(1, 0.2)
    assert fi.is_frozen(1)
    _, delay, _ = fi.intercept(types.SimpleNamespace(src=0, dst=1))
    assert 0.0 < delay <= 0.2
    time.sleep(0.25)
    assert not fi.is_frozen(1)          # freeze expires on its own
    assert fi.stats["kills"] == 1 and fi.stats["freezes"] == 1


# ---------------------------------------------------------------------------
# reliability layer: drop → retransmit, never hang
# ---------------------------------------------------------------------------

def test_retry_recovers_fully_dropped_eager_send():
    cfg = _cfg(retry_backoff_s=0.02, retry_tick_s=0.002)
    with Cluster(2, cfg) as c:
        fi = c.fault_injector(seed=3)
        fi.set_link(0, 1, drop=1.0)     # black hole, briefly
        obj = c.ranks[0].runtime.hetero_object(
            np.arange(256, dtype=np.float32))
        c.ranks[0].send(1, "ft_recv", obj, user={"tag": "eager"})
        time.sleep(0.05)                # original + first retries eaten
        assert "eager" not in _got
        fi.clear_link(0, 1)
        assert _wait(lambda: len(_got.get("eager", [])) == 1)
        np.testing.assert_array_equal(
            _got["eager"][0], np.arange(256, dtype=np.float32))
        assert c.ranks[0].stats["retries"] >= 1
        assert c.ranks[0].stats["send_failures"] == 0
        assert fi.stats["dropped"] >= 1


def test_nack_recovers_dropped_rendezvous_chunks():
    """Chunks of a rendezvous stream dropped on the wire: the receiver's
    NACK (+ the sender's tail resend) retransmit exactly the missing
    sequence numbers; the payload still arrives bit-perfect."""
    cfg = _cfg(chunk_bytes=32 << 10, retry_backoff_s=0.02,
               retry_tick_s=0.002)
    with Cluster(2, cfg) as c:
        fi = c.fault_injector(seed=5)
        fi.set_link(0, 1, drop=0.35)    # data direction only; acks clean
        big = np.random.default_rng(0).random((128, 1024)).astype(
            np.float32)                 # 512 KiB → 16 chunks
        obj = c.ranks[0].runtime.hetero_object(big)
        c.ranks[0].send(1, "ft_recv", obj, user={"tag": "rdzv"})
        time.sleep(0.08)
        fi.clear_link(0, 1)             # let the repair cycle finish clean
        assert _wait(lambda: _got.get("rdzv"))
        np.testing.assert_array_equal(_got["rdzv"][0], big)
        assert fi.stats["dropped"] >= 1
        assert c.ranks[1].stats["dup_dropped"] >= 0   # dedup kept it exact
        c.barrier(timeout=60)
        for r in c.ranks:
            g = r.state_gauges()
            assert all(v == 0 for v in g.values()), (r.rank, g)


def test_remove_peer_races_inflight_out_of_order_stream():
    """Sweeping a peer while its rendezvous stream is mid-flight (with
    duplicated + delayed chunks arriving out of order afterwards) must
    neither crash nor leak state, and the pair must work again after a
    reset."""
    cfg = _cfg(chunk_bytes=32 << 10)
    with Cluster(2, cfg) as c:
        fi = c.fault_injector(seed=1)
        fi.set_link(0, 1, delay_s=0.05, dup=0.3)
        big = np.random.default_rng(1).random((128, 1024)).astype(
            np.float32)
        obj = c.ranks[0].runtime.hetero_object(big)
        c.ranks[0].send(1, "ft_recv", obj, user={"tag": "race"})
        time.sleep(0.01)                # stream is now in flight
        c.ranks[1].remove_peer(0)       # receiver gives up on the peer
        c.ranks[0].remove_peer(1)
        fi.clear_link(0, 1)
        time.sleep(0.2)                 # late chunks land on swept state
        c.ranks[0].reset_peer_state()
        c.ranks[1].reset_peer_state()
        small = c.ranks[0].runtime.hetero_object(np.ones(8, np.float32))
        c.ranks[0].send(1, "ft_recv", small, user={"tag": "after"})
        assert _wait(lambda: _got.get("after"))
        c.barrier(timeout=60)
        for r in c.ranks:
            g = r.state_gauges()
            assert all(v == 0 for v in g.values()), (r.rank, g)


def test_barrier_timeout_names_the_culprit():
    """A stuck cluster barrier must say WHAT it is stuck on — in-flight
    network messages, lane backlogs, live stream ids — not just time
    out."""
    cfg = _cfg(chunk_bytes=32 << 10)
    with Cluster(2, cfg) as c:
        fi = c.fault_injector(seed=0)
        fi.set_link(0, 1, delay_s=0.5)
        big = np.random.default_rng(2).random((128, 1024)).astype(
            np.float32)
        obj = c.ranks[0].runtime.hetero_object(big)
        c.ranks[0].send(1, "ft_recv", obj, user={"tag": "diag"})
        time.sleep(0.02)
        with pytest.raises(TimeoutError) as ei:
            c.barrier(timeout=0.25)
        msg = str(ei.value)
        assert "cluster barrier timeout" in msg
        assert "in flight" in msg and "ctrl VC" in msg
        fi.clear_link(0, 1)
        assert _wait(lambda: _got.get("diag"))   # then it drains fine
        np.testing.assert_array_equal(_got["diag"][0], big)


# ---------------------------------------------------------------------------
# controller: injectable clock + plans against a live owner map
# ---------------------------------------------------------------------------

def test_elastic_controller_runs_on_injected_clock():
    """Regression for the wall-clock dependency: detection must follow
    the injected monotonic clock only — a fake clock drives the whole
    timeout logic with zero real sleeping."""
    t = [100.0]
    ctrl = ElasticController([0, 1, 2], heartbeat_timeout=5.0,
                             clock=lambda: t[0])
    assert ctrl.detect_failures() == []
    t[0] += 4.9
    ctrl.heartbeat(1)                   # stamped at fake-now
    assert ctrl.detect_failures() == [] # nobody past 5.0 yet
    t[0] += 4.9                         # workers 0,2 now 9.8 stale
    assert sorted(ctrl.detect_failures()) == [0, 2]
    assert ctrl.alive_workers() == [1]
    ctrl.heartbeat(0)                   # a late heartbeat revives
    assert sorted(ctrl.alive_workers()) == [0, 1]
    # explicit timestamps (receiver-side arrival times) also work
    ctrl.heartbeat(1, now=t[0] - 5.1)
    assert ctrl.detect_failures() == [1]


def test_plans_execute_against_live_owner_map():
    ctrl = ElasticController([0, 1, 2, 3], heartbeat_timeout=10.0)
    owner = OwnerMap()
    for oid in range(8):
        owner.assign(oid, oid % 4)
    ctrl.health[3].alive = False
    plan = ctrl.shrink_plan(owner, [3])
    assert {oid for oid, _, _ in plan} == {3, 7}
    assert all(old == 3 for _, old, _ in plan)
    for oid, rank in owner.items():     # nothing points at the dead rank
        assert rank != 3
    for oid, _, new in plan:            # the map reflects the plan
        assert owner.owner(oid) == new

    plan = ctrl.grow_plan(owner, [3])
    assert plan, "rebalance must move chunks onto the (re)joined rank"
    assert all(dst == 3 for _, _, dst in plan)
    for oid, _, dst in plan:
        assert owner.owner(oid) == dst

    ctrl.heartbeat(1, slowdown=8.0)     # rank 1 is now an 8x straggler
    plan = ctrl.straggler_plan(owner)
    assert plan
    assert all(src == 1 for _, src, _ in plan)
    for oid, _, dst in plan:
        assert dst != 1 and owner.owner(oid) == dst


# ---------------------------------------------------------------------------
# the full loop: heartbeats → detection → migration → resume
# ---------------------------------------------------------------------------

def test_heartbeat_detection_restores_chunks_end_to_end():
    """Kill a rank on the wire; the next polls must detect it through
    missed heartbeats, sweep it from the survivors, replay the owner
    map, and restore its chunks (restore_fn) onto the survivors."""
    with Cluster(3, _cfg()) as c:
        fi = c.fault_injector(seed=0)
        owner = OwnerMap()
        data = {}
        for oid in range(6):
            owner.assign(oid, oid % 3)
            arr = np.full((32,), float(oid), np.float32)
            data[oid] = arr
            r = c.ranks[oid % 3]
            r.register_object(("chunk", oid), r.runtime.hetero_object(arr))
        er = ElasticRuntime(c, owner, key_fn=lambda o: ("chunk", o),
                            restore_fn=lambda o: data[o],
                            heartbeat_interval_s=0.02,
                            heartbeat_timeout_s=0.15)
        try:
            fi.kill_rank(2)
            dead, deadline = [], time.time() + 20
            while not dead and time.time() < deadline:
                time.sleep(0.03)
                dead = er.poll()["dead"]        # manual, deterministic
            assert dead == [2]
            for oid in (2, 5):                  # rank 2's chunks
                new = owner.owner(oid)
                assert new != 2
                robj = c.ranks[new].objects[("chunk", oid)]
                np.testing.assert_array_equal(np.asarray(robj.get()),
                                              data[oid])
            assert er.stats["recoveries"] == 1
            assert er.stats["bytes_migrated"] > 0
            assert er.epoch == 1
            assert c.ranks[0].stats["recovery_stall_s"] > 0
            assert c.ranks[0].stats["heartbeats_missed"] >= 1
        finally:
            er.close()


def test_jacobi_elastic_kill_revive_bit_exact():
    """The ELASTIC-Recover scenario in miniature (seeded, tier-1): a
    distributed Jacobi run loses a rank after iteration 1's checkpoint
    commits and regains it two iterations later — no restart, and the
    result is bit-identical to the unfaulted elastic run."""
    rng = np.random.default_rng(42)
    u0 = rng.standard_normal((24, 16, 16)).astype(np.float32)
    iters = 4
    with Cluster(3, _cfg()) as c:
        base, rep0 = run_cluster_elastic(u0, iters, c)
    assert rep0["epochs"] == 0          # no fault → no world change
    ref = run_reference(u0, iters)
    np.testing.assert_allclose(base, ref, rtol=1e-5, atol=1e-6)

    with Cluster(3, _cfg()) as c:
        out, rep = run_cluster_elastic(
            u0, iters, c, ckpt_dir=str(_tmp_ckpt_dir()),
            kill=(2, 1), revive_at=(2, 2),
            heartbeat_interval_s=0.02, heartbeat_timeout_s=0.4)
    assert np.array_equal(out, base), "faulted run must be bit-exact"
    e = rep["elastic"]
    assert e["recoveries"] == 1 and e["dead"] == [2]
    assert e["grows"] >= 1              # the revived rank was folded back
    assert e["bytes_migrated"] > 0
    assert rep["monitor_stats"]["recovery_stall_s"] > 0
    assert rep["faults"]["kills"] == 1


def _tmp_ckpt_dir():
    import tempfile
    d = tempfile.mkdtemp(prefix="ft_ckpt_")
    return d


def test_jacobi_straggler_chunks_drain_off_frozen_rank():
    """Freeze one rank's network while it keeps computing: the monitor's
    slowdown fusion must flag it (never declare it dead) and live-migrate
    chunks off it; the run completes and matches the oracle."""
    rng = np.random.default_rng(7)
    u0 = rng.standard_normal((24, 16, 16)).astype(np.float32)
    iters = 3
    with Cluster(3, _cfg()) as c:
        out, rep = run_cluster_elastic(
            u0, iters, c, slabs=6, freeze=(1, 1, 0.6),
            heartbeat_interval_s=0.02, heartbeat_timeout_s=3.0,
            straggler_factor=25.0)
    ref = run_reference(u0, iters)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    e = rep["elastic"]
    assert e["drains"] >= 1
    assert 1 in e["stragglers"]
    assert e["dead"] == []              # frozen ≠ dead
    assert e["chunks_migrated"] >= 1
    sig = e["straggler_signals"][1]
    assert sig["gap_ratio"] >= 25.0     # the heartbeat gap drove it


# ---------------------------------------------------------------------------
# checked-in benchmark rung stays well-formed
# ---------------------------------------------------------------------------

def test_elastic_recover_rung_json_wellformed():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "results", "dryrun",
                        "rt_ladder__ELASTIC-Recover__dev2.json")
    if not os.path.exists(path):
        pytest.skip("ELASTIC-Recover rung JSON not generated")
    with open(path) as f:
        row = json.load(f)
    assert "error" not in row, row
    need = {"n", "iters", "ranks", "ctrl_billed", "oracle_ok",
            "fail_recover", "straggler"}
    assert not (need - set(row)), row
    fr = row["fail_recover"]
    assert fr["recoveries"] >= 1 and fr["bitwise_identical"] is True, fr
    assert fr["bytes_migrated"] > 0, fr
    assert 0 < fr["recovery_stall_s"] < fr["wall_s"], fr
    st = row["straggler"]
    assert st["drains"] >= 1 and st["dead_detected"] == [], st
    assert st["chunks_migrated"] >= 1 and st["oracle_ok"] is True, st
