"""Per-kernel shape/dtype sweeps asserting allclose against the ref oracles
(interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384),
                                   (128, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes(m, k, n, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), dtype)
    got = ops.matmul(a, b)
    want = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(mt=st.integers(1, 3), kt=st.integers(1, 3), nt=st.integers(1, 3))
def test_matmul_property(mt, kt, nt):
    m, k, n = 128 * mt, 128 * kt, 128 * nt
    a = jax.random.normal(KEY, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 7), (k, n), jnp.float32)
    got = ops.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("shape,bx", [((18, 10, 12), 4), ((34, 18, 18), 8),
                                      ((10, 34, 6), 8)])
def test_jacobi3d_kernel(shape, bx):
    u = jax.random.normal(KEY, shape, jnp.float32)
    got = ops.jacobi3d(u, bx=bx)
    want = ref.jacobi3d_ref(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bc,q,h,p,n", [(2, 16, 4, 8, 16), (1, 32, 2, 16, 8),
                                        (4, 8, 8, 4, 4)])
def test_ssd_chunk_kernel(bc, q, h, p, n):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bc, q, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bc, q, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (bc, q, n))
    C = jax.random.normal(ks[4], (bc, q, n))
    gy, gs = ops.ssd_chunk(x, dt, A, B, C)
    wy, ws = ref.ssd_chunk_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(wy), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("s,t,d,causal", [(256, 256, 64, True),
                                          (128, 256, 64, False),
                                          (256, 128, 32, False)])
def test_flash_kernel(s, t, d, causal):
    if causal:
        t = s
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (4, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (4, t, d), jnp.float32)
    v = jax.random.normal(ks[2], (4, t, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_model_flash_matches_pallas():
    """The scan-based model attention and the Pallas kernel agree."""
    from repro.models.attention import flash_attention as model_flash
    ks = jax.random.split(KEY, 3)
    b, s, kh, g, d = 2, 256, 2, 2, 32
    q = jax.random.normal(ks[0], (b, s, kh, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    got = model_flash(q, k, v, q_positions=jnp.arange(s),
                      kv_positions=jnp.arange(s), causal=True, q_block=128,
                      kv_block=128)
    # fold to [B*KH*G, S, D] with GQA broadcast for the kernel
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kh * g, s, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3)[:, :, None], g, 2
                    ).reshape(b * kh * g, s, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3)[:, :, None], g, 2
                    ).reshape(b * kh * g, s, d)
    want = ops.flash_attention(qf, kf, vf, causal=True)
    want = want.reshape(b, kh, g, s, d).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
