"""InterconnectModel: deterministic EWMA convergence (observations are
fed directly — the model is clock-free), bandwidth-delay-product chunk
sizing with quantization + hysteresis, the startup micro-probe against a
FakeDevice with known latencies, and the topology-derived gravity
penalty (ROADMAP follow-up b)."""
import time

import numpy as np
import pytest

from repro.core import (DataGravityPolicy, InterconnectModel, Runtime,
                        RuntimeConfig)
from repro.core.device_api import Device, DeviceInfo
from repro.core.hetero_object import HOST
from repro.core.topology import (DEFAULT_BANDWIDTH, DEFAULT_LATENCY,
                                 probe_runtime_links)


# ---------------------------------------------------------------------------
# EWMA convergence (deterministic: samples fed directly)
# ---------------------------------------------------------------------------

def test_first_sample_replaces_default():
    m = InterconnectModel()
    assert m.bandwidth(0, 1) == DEFAULT_BANDWIDTH
    # 1 MB in 1 ms -> ~1.05 GB/s after latency subtraction
    m.observe(0, 1, 1 << 20, 1e-3)
    bw = m.bandwidth(0, 1)
    assert bw != DEFAULT_BANDWIDTH
    expect = (1 << 20) / (1e-3 - DEFAULT_LATENCY)
    assert bw == pytest.approx(expect, rel=1e-6)


def test_ewma_converges_to_true_bandwidth():
    m = InterconnectModel(alpha=0.25)
    true_bw = 2e9
    nb = 4 << 20
    for _ in range(40):
        m.observe(0, 1, nb, m.latency(0, 1) + nb / true_bw)
    assert m.bandwidth(0, 1) == pytest.approx(true_bw, rel=0.01)
    assert m.samples(0, 1) == 40


def test_small_transfers_update_latency_not_bandwidth():
    m = InterconnectModel()
    bw0 = m.bandwidth(0, 1)
    for _ in range(10):
        m.observe(0, 1, 256, 5e-6)      # tiny: dispatch-dominated
    assert m.bandwidth(0, 1) == bw0     # untouched
    assert m.latency(0, 1) == pytest.approx(5e-6, rel=0.05)


def test_links_are_directed_and_independent():
    m = InterconnectModel()
    m.observe(0, 1, 1 << 20, 1e-3)
    assert m.bandwidth(1, 0) == DEFAULT_BANDWIDTH
    assert m.samples(1, 0) == 0


def test_cost_s_is_latency_plus_bytes_over_bandwidth():
    m = InterconnectModel()
    m.observe(0, 1, 1 << 20, 1e-3)
    lat, bw = m.latency(0, 1), m.bandwidth(0, 1)
    assert m.cost_s(0, 1, 8 << 20) == pytest.approx(
        lat + (8 << 20) / bw, rel=1e-9)


# ---------------------------------------------------------------------------
# chunk sizing: BDP, quantization, clamps, hysteresis
# ---------------------------------------------------------------------------

def test_chunk_bytes_is_quantized_power_of_two_and_clamped():
    m = InterconnectModel()
    nb = 8 << 20
    m.observe(0, 1, nb, m.latency(0, 1) + nb / 1e9)   # ~1 GB/s
    c = m.chunk_bytes(0, 1, 2e-3)                      # BDP ~2 MB
    assert c & (c - 1) == 0                            # power of two
    assert c == 2 << 20
    # clamps survive degenerate estimates
    m2 = InterconnectModel(default_bandwidth=1e3)      # absurdly slow
    assert m2.chunk_bytes(0, 1, 1e-3, lo=64 << 10, hi=4 << 20) == 64 << 10
    m3 = InterconnectModel(default_bandwidth=1e15)     # absurdly fast
    assert m3.chunk_bytes(0, 1, 1e-3, lo=64 << 10, hi=4 << 20) == 4 << 20


def test_chunk_bytes_hysteresis_keeps_choice_stable():
    m = InterconnectModel(alpha=0.5)
    nb = 8 << 20
    m.observe(0, 1, nb, m.latency(0, 1) + nb / 1e9)    # ~1 GB/s
    first = m.chunk_bytes(0, 1, 2e-3)
    # small drift (×1.5) stays inside the band: choice must not move
    m.observe(0, 1, nb, m.latency(0, 1) + nb / 1.5e9)
    assert m.chunk_bytes(0, 1, 2e-3) == first
    # an order-of-magnitude shift escapes the band
    for _ in range(20):
        m.observe(0, 1, nb, m.latency(0, 1) + nb / 40e9)
    assert m.chunk_bytes(0, 1, 2e-3) > first


def test_penalty_bytes_scales_with_bandwidth_and_clamps():
    m = InterconnectModel()
    nb = 8 << 20
    m.observe(0, 1, nb, m.latency(0, 1) + nb / 4e9)    # ~4 GB/s
    p = m.penalty_bytes(0, 1, 50e-6)                   # ≈ 200 KB
    assert 64 << 10 <= p <= 1 << 20
    assert p == pytest.approx(4e9 * 50e-6, rel=0.05)
    slow = InterconnectModel(default_bandwidth=1e3)
    assert slow.penalty_bytes(0, 1, 50e-6) == 64 << 10     # floor
    fast = InterconnectModel(default_bandwidth=1e15)
    assert fast.penalty_bytes(0, 1, 50e-6) == 1 << 20      # ceiling


def test_snapshot_shape():
    m = InterconnectModel()
    m.observe(0, 1, 1 << 20, 1e-3)
    snap = m.snapshot()
    assert "0->1" in snap
    assert set(snap["0->1"]) == {"bw_MBps", "lat_us", "samples"}


# ---------------------------------------------------------------------------
# startup micro-probe against a FakeDevice clock
# ---------------------------------------------------------------------------

class FakeDevice(Device):
    """Uploads/transfers sleep a fixed time — the probe's wall-clock
    samples are bounded below by it."""

    def __init__(self, device_id, upload_s=0.0, transfer_s=0.0):
        super().__init__(DeviceInfo(device_id, "cpu", 1 << 30, "fake"))
        self.upload_s = upload_s
        self.transfer_s = transfer_s
        self.uploads = 0
        self.transfers = 0

    def upload(self, host_array):
        self.uploads += 1
        if self.upload_s:
            time.sleep(self.upload_s)
        return np.array(host_array)

    def download(self, dev_array):
        return np.asarray(dev_array)

    def transfer_from(self, src, dev_array):
        self.transfers += 1
        if self.transfer_s:
            time.sleep(self.transfer_s)
        return np.array(dev_array)

    def launch(self, kernel, args, donate=()):
        return kernel(*args)

    def synchronize(self, handle):
        return handle

    def is_ready(self, handle):
        return True


def test_probe_seeds_host_and_ring_links():
    devs = [FakeDevice(0, upload_s=0.002), FakeDevice(1, upload_s=0.002)]
    m = InterconnectModel()
    probe_runtime_links(m, devs, nbytes=64 << 10)
    for d in (0, 1):
        assert m.samples(HOST, d) == 1
    assert m.samples(0, 1) == 1 and m.samples(1, 0) == 1
    # a 2 ms sleep on 64 KB bounds the measured bandwidth from above
    assert m.bandwidth(HOST, 0) <= (64 << 10) / 0.002 * 1.05
    assert devs[0].uploads == 1 and devs[1].uploads == 1


def test_probe_single_device_skips_ring():
    dev = FakeDevice(0)
    m = InterconnectModel()
    probe_runtime_links(m, [dev], nbytes=16 << 10)
    assert m.samples(HOST, 0) == 1
    assert dev.transfers == 0


# ---------------------------------------------------------------------------
# runtime integration
# ---------------------------------------------------------------------------

def test_runtime_stats_surface_topology_and_probe_seeds():
    with Runtime(RuntimeConfig(memory_capacity=1 << 26)) as rt:
        snap = rt.stats()["topology"]
        # the startup probe seeded at least the host→device links
        for d in rt.devices:
            key = f"{HOST}->{d.info.device_id}"
            assert key in snap and snap[key]["samples"] >= 1


def test_runtime_topology_probe_off():
    cfg = RuntimeConfig(memory_capacity=1 << 26, topology_probe=False)
    with Runtime(cfg) as rt:
        assert rt.stats()["topology"] == {}


def test_transfers_refine_the_model_online():
    with Runtime(RuntimeConfig(memory_capacity=1 << 26)) as rt:
        d0 = rt.devices[0].info.device_id
        before = rt.topology.samples(HOST, d0)
        x = rt.hetero_object(np.ones((128, 128), np.float32))
        rt._ensure_on_device(x, d0, will_write=False)
        assert rt.topology.samples(HOST, d0) == before + 1


def test_gravity_penalty_derived_from_measured_bandwidth():
    pol = DataGravityPolicy(load_penalty_bytes=123)
    # unbound: the fixed fallback constant
    assert pol.penalty_bytes(0) == 123
    m = InterconnectModel()
    nb = 8 << 20
    m.observe(HOST, 0, nb, m.latency(HOST, 0) + nb / 4e9)
    pol.bind_topology(m)
    p = pol.penalty_bytes(0)
    assert p != 123
    assert p == pytest.approx(4e9 * pol.penalty_seconds, rel=0.05)


def test_gravity_transfer_cost_estimate_uses_topology():
    from repro.core import HeteroObject, HeteroTask
    pol = DataGravityPolicy()
    m = InterconnectModel()
    nb = 8 << 20
    m.observe(HOST, 0, nb, m.latency(HOST, 0) + nb / 2e9)
    pol.bind_topology(m)
    o = HeteroObject(None, value=np.zeros(1 << 18, np.float32))  # 1 MB
    t = HeteroTask()
    t.arg(o).read()
    cost = pol.transfer_cost_s(t, 0)
    assert cost == pytest.approx(m.cost_s(HOST, 0, o.nbytes), rel=1e-9)
    # runtime binds it automatically
    with Runtime(RuntimeConfig(memory_capacity=1 << 26)) as rt:
        assert rt.scheduler.placement.topology is rt.topology
