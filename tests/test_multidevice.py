"""Multi-device behaviour via subprocesses with forced host device counts
(tests must not pollute this process's single-device view).

Covers: scheduler spreading work over 4 devices (paper Fig. 9 semantics),
MoE expert-parallel path vs the dense oracle on a real 8-device mesh, and
SPMD Jacobi on a sharded axis.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_scheduler_uses_all_devices():
    out = run_py("""
        import numpy as np, json, collections
        from repro.core import Runtime, RuntimeConfig
        with Runtime(RuntimeConfig(scheduler='least_loaded',
                                   memory_capacity=1<<26)) as rt:
            assert len(rt.devices) == 4, len(rt.devices)
            objs = [rt.hetero_object(np.ones((64, 64), np.float32))
                    for _ in range(16)]
            tasks = []
            for o in objs:
                tasks.append(rt.run(lambda v: (v @ v.T).astype(v.dtype),
                                    [(o, 'rw')]))
            rt.barrier()
            used = collections.Counter(t.chosen_device for t in tasks)
            print(json.dumps(dict(used)))
    """)
    used = json.loads(out.strip().splitlines()[-1])
    assert len(used) >= 3, f"work not spread across devices: {used}"


def test_locality_scheduler_prefers_resident_device():
    out = run_py("""
        import numpy as np
        from repro.core import Runtime, RuntimeConfig
        with Runtime(RuntimeConfig(scheduler='locality',
                                   memory_capacity=1<<26)) as rt:
            x = rt.hetero_object(np.ones((128, 128), np.float32))
            t1 = rt.run(lambda v: v + 1, [(x, 'rw')])
            rt.barrier()
            home = t1.chosen_device
            devs = []
            for _ in range(5):
                t = rt.run(lambda v: v + 1, [(x, 'rw')])
                rt.barrier()
                devs.append(t.chosen_device)
            print('HOME', home, devs)
            assert all(d == home for d in devs), (home, devs)
    """)
    assert "HOME" in out


def test_moe_ep_matches_dense_oracle():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import MoEConfig
        from repro.models import moe as M
        from repro.models.layers import unbox
        from repro.models.sharding import use_sharding
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        mcfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32)
        key = jax.random.PRNGKey(0)
        p, _ = unbox(M.moe_init(key, 16, mcfg, True, dtype=jnp.float32))
        x = jax.random.normal(key, (4, 16, 16), jnp.float32)
        want, aux_d = M.moe_dense(p, x, mcfg, True)
        with use_sharding(mesh):
            got, aux_e = jax.jit(
                lambda p, x: M.moe_ep(p, x, mcfg, True,
                                      capacity_factor=8.0))(p, x)
        # capacity_factor=8 → no drops → exact match expected
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-4, err
        print('EP matches dense:', err)
    """, devices=8)


def test_spmd_jacobi_multidevice():
    run_py("""
        import numpy as np, jax
        from repro.apps.jacobi3d import run_reference, run_spmd
        mesh = jax.make_mesh((4, 1), ('data', 'model'))
        rng = np.random.default_rng(0)
        u0 = rng.random((16, 8, 8)).astype(np.float32)
        want = run_reference(u0, 3)
        for bulk in (False, True):
            got = run_spmd(u0, 3, mesh, axis='data', bulk_sync=bulk)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        print('spmd multidevice ok')
    """)


def test_seq_sharded_decode_matches_plain():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.attention import (decode_attention,
                                            seq_sharded_decode)
        from repro.models.sharding import use_sharding
        mesh = jax.make_mesh((4, 1), ('data', 'model'))
        key = jax.random.PRNGKey(0)
        b, t, kh, g, d = 1, 64, 2, 2, 16
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, kh, g, d))
        kc = jax.random.normal(ks[1], (b, t, kh, d))
        vc = jax.random.normal(ks[2], (b, t, kh, d))
        valid = jnp.arange(t)[None, :] < 50
        want = decode_attention(q, kc, vc, valid=valid)
        with use_sharding(mesh):
            got = jax.jit(lambda *a: seq_sharded_decode(
                a[0], a[1], a[2], valid=a[3], axis='data'))(q, kc, vc, valid)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-5, err
        print('seq-sharded decode ok:', err)
    """)


def test_elastic_restore_to_smaller_mesh(tmp_path):
    """Checkpoint on an 8-device mesh restores onto a 4-device mesh — the
    elastic-rescale path."""
    ckdir = str(tmp_path / "ck")
    run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.checkpoint import Checkpointer
        mesh = jax.make_mesh((8,), ('data',))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh, PS('data')))
        ck = Checkpointer({ckdir!r}, async_save=False)
        ck.save(1, {{'x': x}}, block=True)
        print('saved')
    """, devices=8)
    run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.checkpoint import Checkpointer
        mesh = jax.make_mesh((4,), ('data',))
        ck = Checkpointer({ckdir!r})
        abs_state = {{'x': jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        shardings = {{'x': NamedSharding(mesh, PS('data'))}}
        got = ck.restore(1, abs_state, shardings)
        np.testing.assert_array_equal(np.asarray(got['x']),
                                      np.arange(64.0).reshape(8, 8))
        print('restored on smaller mesh')
    """, devices=4)


def test_sequence_parallel_rules_preserve_numerics():
    """act_seq→model sharding (the SP optimization from §Perf) must not
    change the loss — GSPMD may only reshard, never alter values."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import build_smoke
        from repro.models.layers import unbox
        from repro.models.sharding import use_sharding
        for arch in ('gemma3_27b', 'mamba2_370m'):
            cfg = get_smoke_config(arch)
            m = build_smoke(cfg)
            params, _ = unbox(m.init(jax.random.PRNGKey(0)))
            B, S = 2, 64
            batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1),
                                                  (B, S), 0, cfg.vocab),
                     'labels': jax.random.randint(jax.random.PRNGKey(2),
                                                  (B, S), 0, cfg.vocab)}
            def loss(p, b):
                x, _, aux = m.apply(p, b, mode='train')
                return m.loss(p, x, b['labels']) + aux
            want = float(jax.jit(loss)(params, batch))
            mesh = jax.make_mesh((2, 4), ('data', 'model'))
            with use_sharding(mesh, {'act_seq': 'model'}):
                got = float(jax.jit(loss)(params, batch))
            assert abs(got - want) < 1e-3, (arch, got, want)
            print(arch, 'sp numerics ok', got, want)
    """, devices=8)


def test_seq_sharded_decode_model_axis():
    """kvseq_model variant: seq-sharded cache over the *model* axis with
    batch over data matches plain decode attention."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.attention import (decode_attention,
                                            seq_sharded_decode)
        from repro.models.sharding import use_sharding
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        key = jax.random.PRNGKey(0)
        b, t, kh, g, d = 4, 32, 2, 2, 16
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, kh, g, d))
        kc = jax.random.normal(ks[1], (b, t, kh, d))
        vc = jax.random.normal(ks[2], (b, t, kh, d))
        valid = jnp.broadcast_to(jnp.arange(t)[None, :] < 20, (b, t))
        want = decode_attention(q, kc, vc, valid=valid)
        with use_sharding(mesh):
            got = jax.jit(lambda *a: seq_sharded_decode(
                a[0], a[1], a[2], valid=a[3], axis='model'))(q, kc, vc,
                                                             valid)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-5, err
        print('kvseq over model ok:', err)
    """, devices=8)
