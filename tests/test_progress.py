"""Unified progress engine (core/progress.py) + the behaviours it buys:
lane ordering/identity, completion events, head-of-line freedom for small
messages while a large rendezvous stream is in flight, credit-based
multi-chunk windows on the cut-through network, rendezvous puts, and
lazy first-use topology probing."""
import threading
import time

import numpy as np
import pytest

from repro.core import InterconnectModel, ProgressEngine, Runtime, \
    RuntimeConfig
from repro.core.hetero_object import HOST
from repro.distributed import Cluster, handler

# ---------------------------------------------------------------------------
# engine mechanics
# ---------------------------------------------------------------------------


def test_lane_priority_and_fifo_order():
    eng = ProgressEngine(name="t")
    try:
        gate = threading.Event()
        order = []
        ln = eng.lane("x", 0)
        ln.submit(gate.wait)
        ln.submit(lambda: order.append("deep"), priority=2)
        ln.submit(lambda: order.append("next"), priority=1)
        ln.submit(lambda: order.append("next2"), priority=1)
        gate.set()
        deadline = time.time() + 5
        while len(order) < 3 and time.time() < deadline:
            time.sleep(0.002)
        assert order == ["next", "next2", "deep"]
    finally:
        eng.shutdown()


def test_lane_identity_and_lazy_creation():
    eng = ProgressEngine(name="t")
    try:
        assert eng.lanes_snapshot() == {}
        a = eng.lane("transfer", 0)
        b = eng.lane("transfer", 0)
        c = eng.lane("transfer", 1)
        assert a is b and a is not c
        assert set(eng.lanes_snapshot()) == {"transfer-0", "transfer-1"}
    finally:
        eng.shutdown()


def test_lane_posts_result_and_error_to_future():
    from repro.core.futures import HFuture
    eng = ProgressEngine(name="t")
    try:
        ok = eng.lane("x", 0).submit(lambda: 41 + 1, HFuture())
        assert ok.get(5) == 42
        bad = eng.lane("x", 0).submit(
            lambda: (_ for _ in ()).throw(ValueError("boom")), HFuture())
        with pytest.raises(ValueError):
            bad.get(5)
    finally:
        eng.shutdown()


def test_completion_event_fires_after_waiter():
    eng = ProgressEngine(name="t")
    try:
        gate = threading.Event()
        fired = threading.Event()
        seen = {}

        def callback(result, error):
            seen["result"], seen["error"] = result, error
            fired.set()

        eng.complete("complete", 0, waiter=lambda: gate.wait(5) and "done",
                     callback=callback)
        assert not fired.wait(0.05)          # blocked on the waiter
        gate.set()
        assert fired.wait(5)
        assert seen == {"result": "done", "error": None}
    finally:
        eng.shutdown()


def test_lane_busy_has_no_popped_but_unmarked_window():
    """Regression: a job popped off the priority queue but not yet
    started must still count as busy — the old `_executing`-only
    accounting was set after PriorityQueue.get() returned, so a lane
    mid-handoff looked idle and Cluster.barrier's all-idle sweep could
    return mid-delivery. The pending counter (moved at submit / in the
    job's finally) closes the window. The queue's `get` is wrapped to
    hold the popped item for a beat, making the window deterministic."""
    from repro.core.futures import HFuture
    eng = ProgressEngine(name="t")
    try:
        ln = eng.lane("x", 0)
        popped = threading.Event()
        orig_get = ln._q.get

        def slow_get(*a, **k):
            item = orig_get(*a, **k)
            if item[2] is not None:        # not the stop sentinel
                popped.set()
                time.sleep(0.05)           # popped-but-unmarked window
            return item

        ln._q.get = slow_get
        # the lane thread is still blocked inside the ORIGINAL get; cycle
        # it once so the next loop iteration picks up the wrapper
        ln.submit(lambda: None, HFuture()).get(5)
        done = HFuture()
        ln.submit(lambda: None, done)
        assert popped.wait(5)
        # inside the window: queue is empty, job not yet marked executing
        assert ln.busy(), "lane looked idle while a popped job was pending"
        done.get(5)
        deadline = time.time() + 5
        while ln.busy() and time.time() < deadline:
            time.sleep(0.002)
        assert not ln.busy()
    finally:
        eng.shutdown()


def test_lane_submit_after_stop_raises_and_fails_future():
    """Regression: submitting to a stopped lane used to enqueue behind
    the infinite-priority stop sentinel — the job never ran and its
    future never resolved (silent hang). It must raise and resolve the
    future with the error."""
    from repro.core.futures import HFuture
    eng = ProgressEngine(name="t")
    ln = eng.lane("x", 0)
    eng.shutdown()
    fut = HFuture()
    with pytest.raises(RuntimeError):
        ln.submit(lambda: 1, fut)
    assert fut.done()
    with pytest.raises(RuntimeError):
        fut.get(1)
    # fire-and-forget submits fail loudly too, not silently
    with pytest.raises(RuntimeError):
        ln.submit(lambda: 1)


def test_engine_error_sink_records_fire_and_forget_errors(capsys):
    """Satellite: fire-and-forget lane errors are routed to the engine's
    error sink (counted, bounded trace) instead of only stderr."""
    eng = ProgressEngine(name="t")
    try:
        eng.lane("x", 0).submit(
            lambda: (_ for _ in ()).throw(ValueError("sunk")))
        deadline = time.time() + 5
        while eng.error_count() == 0 and time.time() < deadline:
            time.sleep(0.002)
        assert eng.error_count() == 1
        assert "ValueError" in eng.errors_snapshot()[0]
        eng.check()                     # not strict: no raise
    finally:
        eng.shutdown()


def test_engine_strict_mode_reraises_on_check():
    eng = ProgressEngine(name="t", strict=True)
    try:
        eng.lane("x", 0).submit(
            lambda: (_ for _ in ()).throw(ValueError("strict-sunk")))
        deadline = time.time() + 5
        while eng.error_count() == 0 and time.time() < deadline:
            time.sleep(0.002)
        with pytest.raises(RuntimeError, match="swallowed"):
            eng.check()
    finally:
        eng.shutdown()


def test_runtime_surfaces_progress_errors_and_strict_barrier():
    cfg = RuntimeConfig(memory_capacity=1 << 26, strict_errors=True,
                        topology_probe=False)
    with Runtime(cfg) as rt:
        assert rt.stats()["progress_errors"] == 0
        rt.engine.lane("transfer", 0).submit(
            lambda: (_ for _ in ()).throw(ValueError("boom")))
        deadline = time.time() + 5
        while rt.engine.error_count() == 0 and time.time() < deadline:
            time.sleep(0.002)
        assert rt.stats()["progress_errors"] == 1
        with pytest.raises(RuntimeError):
            rt.barrier(timeout=10)


def test_busy_reflects_queued_and_executing_work():
    eng = ProgressEngine(name="t")
    try:
        gate = threading.Event()
        eng.lane("x", 0).submit(gate.wait)
        time.sleep(0.05)
        assert eng.busy()
        gate.set()
        deadline = time.time() + 5
        while eng.busy() and time.time() < deadline:
            time.sleep(0.002)
        assert not eng.busy()
    finally:
        eng.shutdown()


def test_runtime_retires_inflight_through_completion_lane():
    """Launch retirement is completion-driven: with a multi-launch window
    the worker must not block per launch, and every task still retires."""
    with Runtime(RuntimeConfig(memory_capacity=1 << 28, inflight=4)) as rt:
        objs = [rt.hetero_object(np.ones((32, 32), np.float32))
                for _ in range(12)]
        for o in objs:
            rt.run(lambda v: v * 2.0, [(o, "rw")])
        rt.barrier(timeout=60)
        lanes = rt.stats()["progress_lanes"]
        complete = [k for k in lanes if k.startswith("complete-")]
        assert complete, lanes
        assert sum(lanes[k]["jobs_done"] for k in complete) >= 12
        for o in objs:
            np.testing.assert_allclose(o.get(), 2.0)


# ---------------------------------------------------------------------------
# head-of-line freedom + credit windows on the message engine
# ---------------------------------------------------------------------------

_echo_lock = threading.Lock()
_echo_state = {}


@handler(name="prog_echo")
def _prog_echo(ctx, obj):
    ctx.send(ctx.message.src, "prog_echo_reply")


@handler(name="prog_echo_reply")
def _prog_echo_reply(ctx, obj):
    with _echo_lock:
        evt = _echo_state.get("evt")
    if evt is not None:
        evt.set()


@handler(name="prog_sink")
def _prog_sink(ctx, obj):
    with _echo_lock:
        evt = _echo_state.get("stream_done")
    if evt is not None:
        evt.set()


def _round_trip(cluster, timeout=10.0) -> float:
    evt = threading.Event()
    with _echo_lock:
        _echo_state["evt"] = evt
    t0 = time.perf_counter()
    cluster.ranks[0].send(1, "prog_echo")
    assert evt.wait(timeout), "echo round-trip timed out"
    return time.perf_counter() - t0


def test_small_messages_not_blocked_behind_rendezvous_stream():
    """Regression (ROADMAP follow-up a): while an 8 MiB rendezvous stream
    is in flight rank0→rank1, small round-trips on the same rank pair
    must keep completing — the pump no longer streams the payload inline,
    and control traffic rides a higher-priority virtual channel."""
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=256 << 10)
    # 8 MiB at 256 MB/s ≈ 31 ms on the wire, 32 chunks of ~1 ms each
    with Cluster(2, cfg, latency_s=20e-6, bw_bytes_per_s=256e6) as cluster:
        with _echo_lock:
            _echo_state.clear()
        for _ in range(3):                   # warm compile + thread paths
            _round_trip(cluster)
        unloaded = min(_round_trip(cluster) for _ in range(5))
        stream_done = threading.Event()
        with _echo_lock:
            _echo_state["stream_done"] = stream_done
        data = np.ones((8 << 20) // 4, np.float32)
        obj = cluster.ranks[0].runtime.hetero_object(data)
        cluster.ranks[0].send(1, "prog_sink", obj)
        completed, loaded = 0, []
        while not stream_done.is_set() and completed < 50:
            loaded.append(_round_trip(cluster))
            completed += 1
        assert stream_done.wait(30)
        # the old inline-streaming pump completed ~0 round-trips during
        # the stream; the progress engine interleaves freely
        assert completed >= 3, (completed, unloaded)
        # p50 while loaded stays bounded: generous 25x/25ms ceiling so CI
        # noise can't flake, while the pre-refactor behaviour (a whole
        # 31 ms stream ahead of every reply) still fails it
        p50 = float(np.median(loaded))
        assert p50 < max(unloaded * 25, 0.025), (p50, unloaded)
        cluster.barrier()


def test_multi_chunk_window_keeps_pipeline_full():
    """Credit-based flow control: ≥2 chunks in flight per stream (the
    initial CTS window), and the cut-through network lets receive-side
    uploads complete while later chunks are still on the wire —
    overlap_bytes grows."""
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=128 << 10)
    with Cluster(2, cfg, latency_s=30e-6, bw_bytes_per_s=2e8) as cluster:
        with _echo_lock:
            _echo_state.clear()
        stream_done = threading.Event()
        with _echo_lock:
            _echo_state["stream_done"] = stream_done
        data = np.arange((2 << 20) // 4, dtype=np.float32)   # 16 chunks
        obj = cluster.ranks[0].runtime.hetero_object(data)
        cluster.ranks[0].send(1, "prog_sink", obj)
        assert stream_done.wait(30)
        cluster.barrier()
        s0, s1 = cluster.ranks[0].stats, cluster.ranks[1].stats
        assert s0["rendezvous"] == 1
        assert s0["chunks_out"] == 16
        assert s0["max_window"] >= 2, s0
        assert s0["credits_in"] > 0, s0
        assert s1["overlap_bytes"] > 0, s1


def test_sender_pump_returns_before_stream_finishes():
    """Cut-through: Cluster.deliver never blocks the caller for the
    transmission time — the link lane serializes it. A 4 MiB payload at
    64 MB/s occupies the wire ~63 ms; the send-side flush must hand it
    off in far less."""
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=1 << 30)   # monolithic eager send
    with Cluster(2, cfg, latency_s=0.0, bw_bytes_per_s=64e6) as cluster:
        with _echo_lock:
            _echo_state.clear()
        stream_done = threading.Event()
        with _echo_lock:
            _echo_state["stream_done"] = stream_done
        data = np.ones((4 << 20) // 4, np.float32)
        obj = cluster.ranks[0].runtime.hetero_object(data)
        t0 = time.perf_counter()
        cluster.ranks[0].send(1, "prog_sink", obj).get(10)
        # wait for the pump to flush the payload onto the link
        deadline = time.time() + 10
        while time.time() < deadline:
            with cluster.ranks[0]._out_lock:
                if not cluster.ranks[0].outgoing:
                    break
            time.sleep(0.001)
        handed_off = time.perf_counter() - t0
        assert stream_done.wait(30)
        wire_time = (4 << 20) / 64e6
        assert handed_off < wire_time / 2, (handed_off, wire_time)
        cluster.barrier()


# ---------------------------------------------------------------------------
# lazy topology probing (ROADMAP follow-up c)
# ---------------------------------------------------------------------------

def test_lazy_probe_measures_pair_on_first_d2d():
    cfg = RuntimeConfig(memory_capacity=1 << 28, topology_probe=False,
                        lazy_probe=True)
    with Runtime(cfg) as rt:
        if len(rt.devices) < 2:
            pytest.skip("needs >= 2 (virtual) devices")
        obj = rt.hetero_object(np.ones((64, 64), np.float32))
        rt._ensure_on_device(obj, 0, will_write=False)
        assert not rt.topology.measured(0, 1)
        rt._ensure_on_device(obj, 1, will_write=False)
        # first use probed the pair (one micro-probe + the transfer's own
        # observation)
        assert rt.topology.measured(0, 1)
        assert rt.topology.samples(0, 1) >= 2


def test_seed_from_path_composes_measured_hops():
    m = InterconnectModel()
    m.observe(HOST, 0, 1 << 20, 1e-3)      # ~1 GB/s
    m.observe(HOST, 1, 1 << 20, 2e-3)      # ~0.5 GB/s
    m.observe(0, HOST, 1 << 20, 1e-3)
    assert m.seed_from_path(0, 1, via=HOST)
    # bottleneck bandwidth, summed latency; still counts as unmeasured
    assert m.bandwidth(0, 1) == pytest.approx(
        min(m.bandwidth(0, HOST), m.bandwidth(HOST, 1)))
    assert not m.measured(0, 1)
    # a real sample replaces the seed outright
    m.observe(0, 1, 1 << 20, 0.5e-3)
    assert m.measured(0, 1)
    # seeding never overwrites measured links
    assert not m.seed_from_path(0, 1, via=HOST)


def test_window_chunks_covers_bdp_and_clamps():
    m = InterconnectModel()
    m.observe(1, 2, 10 << 20, 10e-3)       # ~1 GB/s bandwidth sample
    m.observe(1, 2, 1 << 10, 1e-3)         # 1 ms latency sample
    w = m.window_chunks(1, 2, 256 << 10)
    # BDP = bw * 2*latency ≈ 2 MB → ≈ 8 chunks (+1), within clamps
    assert 2 <= w <= 16
    assert w >= 8
    assert m.window_chunks(1, 2, 1 << 30) == 2          # floor
    assert m.window_chunks(1, 2, 1) == 16               # cap


# ---------------------------------------------------------------------------
# adaptive credit-window controller (ROADMAP follow-up a)
# ---------------------------------------------------------------------------

def test_window_controller_shrinks_to_one_and_rewidens():
    """AIMD controller: backlog halves the window (never below 1, even
    under sustained pressure), an empty queue widens it back toward the
    BDP ceiling, and feedback-free calls stay the static BDP sizing."""
    m = InterconnectModel()
    m.observe(0, 1, 10 << 20, 10e-3)       # ~1 GB/s
    m.observe(0, 1, 1 << 10, 1e-3)         # 1 ms latency
    bdp_win = m.window_chunks(0, 1, 256 << 10)
    assert bdp_win >= 8
    assert m.current_window(0, 1) is None  # static query: no state
    # sustained backlog: halve per decision, floor at 1
    seen = [m.window_chunks(0, 1, 256 << 10, queue_depth=4)
            for _ in range(8)]
    assert seen[0] == max(bdp_win // 2, 1)
    assert seen[-1] == 1 and min(seen) == 1
    assert all(w >= 1 for w in seen)
    assert m.current_window(0, 1) == 1
    # drained: additive re-widen toward (and capped at) the BDP window
    grown = [m.window_chunks(0, 1, 256 << 10, queue_depth=0)
             for _ in range(bdp_win + 4)]
    assert grown[0] == 2
    assert grown[-1] == bdp_win
    assert max(grown) == bdp_win
    # feedback-free call still returns the static sizing, untouched
    assert m.window_chunks(0, 1, 256 << 10) == bdp_win


def test_window_controller_shrinks_on_slab_occupancy():
    from repro.core.topology import WINDOW_SLAB_LIMIT
    m = InterconnectModel()
    w0 = m.window_chunks(0, 1, 64 << 10, queue_depth=0)
    w = m.window_chunks(0, 1, 64 << 10, queue_depth=0,
                        slab_bytes=WINDOW_SLAB_LIMIT + 1)
    assert w == max(w0 // 2, 1)


def _throttle_transfer_lane(lane, stop_evt, busy_s=0.004, cap=4):
    """Keep a transfer lane artificially backed up: inject sleeper jobs
    (bounded backlog) so chunk uploads queue behind them — the slowed
    receiver the adaptive window must react to."""
    def pump():
        while not stop_evt.is_set():
            if lane.pending() < cap:
                try:
                    lane.submit(lambda: time.sleep(busy_s))
                except RuntimeError:
                    return
            time.sleep(busy_s / 4)
    t = threading.Thread(target=pump, daemon=True)
    t.start()
    return t


def test_adaptive_window_shrinks_under_slowed_receiver_lane():
    """Tentpole: with the receiver's transfer lane backed up, the credit
    controller shrinks the window mid-stream (credits withheld), the
    stream still completes bit-exact, and the receiver records the
    adaptation. A pinned net_window must bypass all of it."""
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=128 << 10)
    with Cluster(2, cfg, latency_s=30e-6, bw_bytes_per_s=1e8) as cluster:
        with _echo_lock:
            _echo_state.clear()
        # seed a fat link estimate so the stream OPENS wide (BDP ≈ 2 MB →
        # a 16-chunk window) and the shrink is observable mid-stream
        cluster.topology.observe(0, 1, 10 << 20, 10e-3)   # ~1 GB/s
        cluster.topology.observe(0, 1, 1 << 10, 1e-3)     # 1 ms latency
        r1 = cluster.ranks[1]
        r1.route_to("prog_sink", 0)        # pin the landing device
        lane = r1.runtime.engine.lane("transfer", 0)
        stop = threading.Event()
        try:
            stream_done = threading.Event()
            with _echo_lock:
                _echo_state["stream_done"] = stream_done
            data = np.arange((4 << 20) // 4, dtype=np.float32)  # 32 chunks
            obj = cluster.ranks[0].runtime.hetero_object(data.copy())
            cluster.ranks[0].send(1, "prog_sink", obj)
            # let the CTS grant the wide window, then back the lane up:
            # the controller must shrink MID-STREAM, withholding credits
            time.sleep(0.003)
            _throttle_transfer_lane(lane, stop)
            assert stream_done.wait(60)
        finally:
            stop.set()
        cluster.barrier(timeout=60)
        s1 = r1.stats
        assert s1["window_adjusts"] > 0, s1
        assert s1["credits_deferred"] > 0, s1
        assert 1 <= s1["window_min"] <= 2, s1    # shrank under backlog
        assert s1["rx_queue_peak"] >= 2, s1
        # the shared-link controller remembers the shrunken window
        assert cluster.topology.current_window(0, 1) is not None
        assert cluster.topology.current_window(0, 1) <= 4


def test_adaptive_window_rewidens_after_drain():
    """After a throttled stream shrank the window, an unthrottled stream
    on the same link must widen it back (credits re-granted, coalesced)."""
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=128 << 10)
    with Cluster(2, cfg, latency_s=30e-6, bw_bytes_per_s=5e8) as cluster:
        with _echo_lock:
            _echo_state.clear()
        r1 = cluster.ranks[1]
        r1.route_to("prog_sink", 0)
        lane = r1.runtime.engine.lane("transfer", 0)

        def one_stream():
            stream_done = threading.Event()
            with _echo_lock:
                _echo_state["stream_done"] = stream_done
            data = np.ones((4 << 20) // 4, np.float32)
            obj = cluster.ranks[0].runtime.hetero_object(data)
            cluster.ranks[0].send(1, "prog_sink", obj)
            assert stream_done.wait(60)
            cluster.barrier(timeout=60)

        stop = threading.Event()
        _throttle_transfer_lane(lane, stop)
        try:
            one_stream()                    # shrinks the window
        finally:
            stop.set()
        shrunk = cluster.topology.current_window(0, 1)
        assert shrunk is not None and shrunk <= 2, shrunk
        deadline = time.time() + 10         # let the sleeper backlog drain
        while lane.busy() and time.time() < deadline:
            time.sleep(0.005)
        one_stream()                        # drained: widens back
        rewidened = cluster.topology.current_window(0, 1)
        assert rewidened > shrunk, (shrunk, rewidened)


def test_pinned_net_window_bypasses_adaptation():
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=128 << 10,
                        net_window=4)
    with Cluster(2, cfg, latency_s=30e-6, bw_bytes_per_s=5e8) as cluster:
        with _echo_lock:
            _echo_state.clear()
        r1 = cluster.ranks[1]
        r1.route_to("prog_sink", 0)
        lane = r1.runtime.engine.lane("transfer", 0)
        stop = threading.Event()
        _throttle_transfer_lane(lane, stop)
        try:
            stream_done = threading.Event()
            with _echo_lock:
                _echo_state["stream_done"] = stream_done
            data = np.ones((4 << 20) // 4, np.float32)
            obj = cluster.ranks[0].runtime.hetero_object(data)
            cluster.ranks[0].send(1, "prog_sink", obj)
            assert stream_done.wait(60)
        finally:
            stop.set()
        cluster.barrier(timeout=60)
        s1 = cluster.ranks[1].stats
        assert s1["window_adjusts"] == 0, s1
        assert s1["credits_deferred"] == 0, s1
        assert s1["window_min"] == 4, s1
        assert cluster.topology.current_window(0, 1) is None
        assert cluster.ranks[0].stats["max_window"] <= 4
