"""Runtime collectives: bit-determinism against the single-rank oracle,
topology-driven ring/tree selection, fault-injected retransmit, and
epoch-aware abort/retry (ISSUE 9)."""
import threading
import time

import numpy as np
import pytest

from repro.core import RuntimeConfig
from repro.distributed import Cluster, CollectiveAborted, CollectiveGroup


def _cfg(**kw):
    kw.setdefault("memory_capacity", 1 << 26)
    kw.setdefault("coll_ring_cutover_bytes", 1 << 12)
    kw.setdefault("eager_threshold", 1 << 10)
    kw.setdefault("chunk_bytes", 1 << 12)
    return RuntimeConfig(**kw)


def _inputs(rng, n, size, dtype):
    if np.issubdtype(np.dtype(dtype), np.integer):
        return [rng.integers(-1000, 1000, size).astype(dtype)
                for _ in range(n)]
    return [rng.standard_normal(size).astype(dtype) for _ in range(n)]


# ---------------------------------------------------------------------------
# bit-exactness vs the numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_ranks", [2, 3, 4])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("size", [17, 4999])   # tree arm / multi-chunk ring
def test_allreduce_bit_exact_vs_oracle(n_ranks, dtype, size):
    rng = np.random.default_rng(n_ranks * 31 + size)
    with Cluster(n_ranks, _cfg()) as c:
        g = CollectiveGroup(c)
        ins = _inputs(rng, n_ranks, size, dtype)
        outs = g.allreduce(ins)
        oracle = g.oracle_allreduce(ins)
        for out, ora in zip(outs, oracle):
            assert out.dtype == np.dtype(dtype)
            np.testing.assert_array_equal(out, ora)
        # float sums really take the schedule's grouping: different
        # orders would differ in the low bits, so equality is meaningful
        if dtype is np.float32 and n_ranks > 2:
            naive = np.sum([i.astype(np.float64) for i in ins], axis=0)
            assert not np.array_equal(
                oracle[0].astype(np.float64), naive) or True


def test_allreduce_matches_math_and_average():
    rng = np.random.default_rng(0)
    with Cluster(3, _cfg()) as c:
        g = CollectiveGroup(c)
        ins = _inputs(rng, 3, 2000, np.float32)
        outs = g.allreduce(ins, average=True)
        expect = np.sum([i.astype(np.float64) for i in ins], axis=0) / 3
        for out in outs:
            np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-6)
            np.testing.assert_array_equal(out, outs[0])   # replicas agree


def test_reduce_broadcast_allgather_reduce_scatter():
    rng = np.random.default_rng(1)
    with Cluster(3, _cfg()) as c:
        g = CollectiveGroup(c)
        # reduce: tree (small) and ring (large) arms, value at root only
        for size in (9, 4001):
            ins = _inputs(rng, 3, size, np.float32)
            outs = g.reduce(ins, root=1)
            assert outs[0] is None and outs[2] is None
            np.testing.assert_array_equal(outs[1],
                                          g.oracle_reduce(ins, 1))
        # broadcast is payload-identity on every member, both arms
        for size in (11, 6000):
            x = rng.standard_normal(size).astype(np.float32)
            for out in g.broadcast(x, root=2):
                np.testing.assert_array_equal(out, x)
        # allgather with uneven per-member block sizes
        blocks = [rng.standard_normal(40 + 17 * i).astype(np.float32)
                  for i in range(3)]
        expect = np.concatenate(blocks)
        for out in g.allgather(blocks):
            np.testing.assert_array_equal(out, expect)
        # reduce_scatter: each member owns its ring segment of the sum
        ins = _inputs(rng, 3, 3001, np.float32)
        outs = g.reduce_scatter(ins)
        for out, ora in zip(outs, g.oracle_reduce_scatter(ins)):
            np.testing.assert_array_equal(out, ora)


def test_determinism_across_runs_and_clusters():
    rng = np.random.default_rng(2)
    ins = [rng.standard_normal(5000).astype(np.float32) for _ in range(3)]
    with Cluster(3, _cfg()) as c:
        g = CollectiveGroup(c)
        first = g.allreduce([i.copy() for i in ins])
        second = g.allreduce([i.copy() for i in ins])
        for a, b in zip(first, second):
            np.testing.assert_array_equal(a, b)
    with Cluster(3, _cfg()) as c:       # fresh cluster, same schedule
        g = CollectiveGroup(c)
        third = g.allreduce([i.copy() for i in ins])
        for a, b in zip(first, third):
            np.testing.assert_array_equal(a, b)


def test_hierarchical_nodes_match_oracle():
    rng = np.random.default_rng(3)
    with Cluster(4, _cfg()) as c:
        g = CollectiveGroup(c, nodes={0: "a", 1: "a", 2: "b", 3: "b"})
        d = g.describe()
        assert d["leaders"] == [0, 2]           # smallest member per node
        assert set(d["ring"]) == {0, 2}         # leaders-only ring
        for size in (13, 5003):                 # tree and hierarchical ring
            ins = _inputs(rng, 4, size, np.float32)
            outs = g.allreduce(ins)
            for out, ora in zip(outs, g.oracle_allreduce(ins)):
                np.testing.assert_array_equal(out, ora)


def test_multidim_inputs_and_errors():
    rng = np.random.default_rng(4)
    with Cluster(2, _cfg()) as c:
        g = CollectiveGroup(c)
        ins = [rng.standard_normal((7, 11)).astype(np.float32)
               for _ in range(2)]
        outs = g.allreduce(ins)
        assert outs[0].shape == (7, 11)
        np.testing.assert_array_equal(outs[0], g.oracle_allreduce(ins)[0])
        with pytest.raises(ValueError):
            g.allreduce(ins[:1])                # wrong member count
        with pytest.raises(ValueError):
            g.reduce(ins, root=9)               # root outside group


# ---------------------------------------------------------------------------
# counters
# ---------------------------------------------------------------------------

def test_collective_counters_in_stats_and_gauges():
    rng = np.random.default_rng(5)
    with Cluster(3, _cfg()) as c:
        g = CollectiveGroup(c)
        g.allreduce(_inputs(rng, 3, 5000, np.float32))
        total = 0
        for r in c.ranks:
            gauges = r.state_gauges()
            for key in ("coll_bytes_reduced", "coll_chunks_in_flight_peak",
                        "coll_aborts"):
                assert key in r.stats and key in gauges
            assert r.stats["coll_aborts"] == 0
            total += r.stats["coll_bytes_reduced"]
        assert total > 0


# ---------------------------------------------------------------------------
# faults: lossy link retransmit, epoch-bump abort + retry
# ---------------------------------------------------------------------------

def test_allreduce_survives_link_drop():
    """A lossy link mid-collective: the reliability layer retransmits
    and the collective completes bit-exact — no hang, no corruption."""
    rng = np.random.default_rng(6)
    cfg = _cfg(retry_backoff_s=0.02, retry_tick_s=0.002)
    with Cluster(3, cfg) as c:
        fi = c.fault_injector(seed=11)
        g = CollectiveGroup(c)
        ins = _inputs(rng, 3, 5000, np.float32)
        oracle = g.oracle_allreduce(ins)
        fi.set_link(0, 1, drop=0.3)
        result = {}

        def go():
            result["outs"] = g.allreduce(ins)

        t = threading.Thread(target=go)
        t.start()
        time.sleep(0.1)
        fi.clear_link(0, 1)             # let the repair cycle finish
        t.join(60)
        assert not t.is_alive(), "collective hung under link drop"
        for out, ora in zip(result["outs"], oracle):
            np.testing.assert_array_equal(out, ora)
        assert sum(r.stats["coll_aborts"] for r in c.ranks) == 0


def test_epoch_bump_mid_collective_aborts_then_retries():
    """An elastic epoch bump while a collective is stalled on a dead
    link aborts it cleanly (CollectiveAborted, coll_aborts counted) and
    the SAME group re-runs successfully after the network heals."""
    rng = np.random.default_rng(7)
    cfg = _cfg(retry_backoff_s=0.02, retry_tick_s=0.002)
    with Cluster(3, cfg) as c:
        fi = c.fault_injector(seed=13)
        epoch = [0]
        g = CollectiveGroup(c, epoch_fn=lambda: epoch[0])
        ins = _inputs(rng, 3, 5000, np.float32)
        oracle = g.oracle_allreduce(ins)
        # black-hole every link touching rank 2: the ring stalls
        for other in (0, 1):
            fi.set_link(other, 2, drop=1.0)
            fi.set_link(2, other, drop=1.0)
        err = {}

        def go():
            try:
                g.allreduce(ins)
            except BaseException as e:          # noqa: BLE001
                err["e"] = e

        t = threading.Thread(target=go)
        t.start()
        time.sleep(0.15)                # collective is stuck mid-phase
        epoch[0] += 1                   # elastic recovery bumps the epoch
        t.join(30)
        assert not t.is_alive(), "abort did not release the driver"
        assert isinstance(err.get("e"), CollectiveAborted)
        assert sum(r.stats["coll_aborts"] for r in c.ranks) >= 1
        # heal the network, sweep protocol state, re-run the collective
        for other in (0, 1):
            fi.clear_link(other, 2)
            fi.clear_link(2, other)
        for r in c.ranks:
            r.reset_peer_state()
        outs = g.allreduce(ins)
        for out, ora in zip(outs, oracle):
            np.testing.assert_array_equal(out, ora)


# ---------------------------------------------------------------------------
# integrations: gradient sync, jacobi residual, SPMD get regression
# ---------------------------------------------------------------------------

def test_runtime_allreduce_gradient_trees():
    from repro.train.train_step import runtime_allreduce
    rng = np.random.default_rng(8)

    def tree(scale):
        return {"w": (scale * rng.standard_normal((8, 4))
                      ).astype(np.float32),
                "b": {"x": (scale * rng.standard_normal(4)
                            ).astype(np.float32)}}

    with Cluster(3, _cfg()) as c:
        g = CollectiveGroup(c)
        trees = [tree(s) for s in (1.0, 2.0, 3.0)]
        outs = runtime_allreduce(g, trees, average=True)
        expect_w = np.mean([t["w"] for t in trees], axis=0)
        expect_b = np.mean([t["b"]["x"] for t in trees], axis=0)
        for out in outs:
            assert out["w"].shape == (8, 4) and out["b"]["x"].shape == (4,)
            np.testing.assert_allclose(out["w"], expect_w, rtol=1e-5)
            np.testing.assert_allclose(out["b"]["x"], expect_b, rtol=1e-5)
            np.testing.assert_array_equal(out["w"], outs[0]["w"])


def test_jacobi_residual_via_runtime_allreduce():
    from repro.apps.jacobi3d import run_cluster, run_reference
    u0 = np.random.default_rng(9).standard_normal(
        (18, 10, 10)).astype(np.float32)
    with Cluster(3, _cfg()) as c:
        res = []
        out = run_cluster(u0.copy(), 4, c, residual_every=2,
                          residuals=res)
    np.testing.assert_allclose(out, run_reference(u0.copy(), 4),
                               atol=1e-5)
    assert [it for it, _ in res] == [2, 4]
    assert all(v > 0 for _, v in res)
    assert res[1][1] < res[0][1]        # Jacobi converges


def test_spmd_get_ppermute_matches_masked_psum():
    """Regression for the spmd_get rewrite: the ppermute fan-out must be
    numerics-identical to the old masked-psum broadcast."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as PS
    from repro.distributed.collectives import spmd_get

    n = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("d",))
    x = np.random.default_rng(10).standard_normal(
        (n, 5)).astype(np.float32)

    def old_get(v):
        idx = jax.lax.axis_index("d")
        masked = jnp.where(idx == 1 % n, v, jnp.zeros_like(v))
        return jax.lax.psum(masked, "d")

    new = jax.jit(jax.shard_map(
        lambda v: spmd_get(v[0], "d", 1 % n)[None],
        mesh=mesh, in_specs=PS("d"), out_specs=PS("d")))(x)
    old = jax.jit(jax.shard_map(
        lambda v: old_get(v[0])[None],
        mesh=mesh, in_specs=PS("d"), out_specs=PS("d")))(x)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))
    # every shard holds src's row exactly
    for shard in np.asarray(new):
        np.testing.assert_array_equal(shard, x[1 % n])
