"""Over-decomposition planner geometry (paper §4.4): chunk coverage,
neighbour reciprocity, owner balance, and degenerate domains."""
import numpy as np
import pytest

from repro.distributed.overdecomp import (microbatch_plan,
                                          plan_decomposition)


@pytest.mark.parametrize("domain,workers,od", [
    ((32, 16, 16), 2, 2),
    ((64, 8, 8), 4, 2),
    ((16, 16), 4, 1),
    ((24,), 3, 2),
])
def test_chunks_tile_domain_exactly(domain, workers, od):
    plan = plan_decomposition(domain, workers, od)
    assert len(plan.chunks) == workers * od
    covered = np.zeros(domain, dtype=np.int32)
    for c in plan.chunks:
        sl = tuple(slice(lo, hi) for lo, hi in zip(c.lo, c.hi))
        covered[sl] += 1
        assert c.shape == tuple(h - l for l, h in zip(c.lo, c.hi))
    # every cell covered exactly once — no gaps, no overlaps
    assert (covered == 1).all()


@pytest.mark.parametrize("domain,workers,od", [
    ((32, 16, 16), 2, 2),
    ((64, 8, 8), 4, 2),
    ((24,), 3, 2),
])
def test_neighbor_reciprocity(domain, workers, od):
    """If chunk A sees B across its hi-face of dim d, B must see A across
    its lo-face of dim d — halo exchanges depend on this symmetry."""
    plan = plan_decomposition(domain, workers, od)
    for c in plan.chunks:
        for tag, other in plan.neighbors(c.cid).items():
            if other is None:
                continue
            opp = {"lo": "hi", "hi": "lo"}[tag[:2]] + tag[2]
            assert plan.neighbors(other)[opp] == c.cid, (c.cid, tag, other)


def test_neighbor_offsets_are_adjacent():
    plan = plan_decomposition((32, 16, 16), 2, 2)
    for c in plan.chunks:
        for tag, other in plan.neighbors(c.cid).items():
            if other is None:
                continue
            d = int(tag[2:])
            o = plan.chunks[other]
            diff = [a - b for a, b in zip(o.grid_pos, c.grid_pos)]
            want = [0] * len(diff)
            want[d] = -1 if tag.startswith("lo") else 1
            assert diff == want, (c.grid_pos, tag, o.grid_pos)


@pytest.mark.parametrize("workers,od", [
    (2, 4), (4, 3), (3, 1), (16, 1), (2, 1),
])
def test_owner_of_is_balanced_and_monotone(workers, od):
    """Block ownership: every worker owns exactly ``od`` chunks, ids are
    assigned in contiguous monotone blocks, every owner is in range."""
    n_chunks = workers * od
    plan = plan_decomposition((n_chunks * 4,), workers, od)
    owners = [plan.owner_of(c.cid) for c in plan.chunks]
    counts = {r: owners.count(r) for r in range(workers)}
    assert all(counts[r] == od for r in range(workers)), counts
    assert owners == sorted(owners)          # contiguous blocks
    assert all(0 <= r < workers for r in owners)


def test_degenerate_single_worker_single_chunk():
    plan = plan_decomposition((8, 8), n_workers=1, over_decomposition=1)
    assert len(plan.chunks) == 1
    c = plan.chunks[0]
    assert c.lo == (0, 0) and c.hi == (8, 8)
    assert all(v is None for v in plan.neighbors(0).values())
    assert plan.owner_of(0) == 0


def test_degenerate_single_worker_overdecomposed():
    plan = plan_decomposition((16,), n_workers=1, over_decomposition=4)
    assert len(plan.chunks) == 4
    assert all(plan.owner_of(c.cid) == 0 for c in plan.chunks)
    # interior chunks have both neighbours, boundary chunks one
    n0 = plan.neighbors(0)
    assert n0["lo0"] is None and n0["hi0"] == 1


def test_grid_biases_larger_dims():
    """The chunk grid splits the longest dims first — a 64×8×8 domain cut
    into 8 chunks must not split the short dims below need."""
    plan = plan_decomposition((64, 8, 8), 8, 1)
    assert plan.chunk_grid[0] >= max(plan.chunk_grid[1:])
    assert int(np.prod(plan.chunk_grid)) == 8


def test_indivisible_domain_asserts():
    with pytest.raises(AssertionError):
        plan_decomposition((10,), n_workers=3, over_decomposition=1)


def test_microbatch_plan_balance_and_divisibility():
    assert microbatch_plan(256, 4) == [64, 64, 64, 64]
    assert microbatch_plan(8, 1) == [8]
    with pytest.raises(AssertionError):
        microbatch_plan(10, 4)
