"""Concurrency sanitizer + runtime lint: seeded lock-order inversions
are named, lane-discipline violations are recorded, the distributed
wait-for graph turns a black-holed credit cycle into a verdict with
rank/stream names, gauge leaks raise at shutdown — and clean runs
(mini workload, 2-rank allreduce) produce ZERO false positives."""
import ast
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import RuntimeConfig, Runtime
from repro.core import clock, sanitizer
from repro.core.sanitizer import RuntimeSanitizer, SanitizerError
from repro.distributed import Cluster, CollectiveGroup, handler

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))
import lint_runtime  # noqa: E402


@pytest.fixture()
def fresh_global_san():
    """Tests that exercise the process-global sanitizer get a fresh
    install and always leave the process clean."""
    sanitizer.uninstall()
    yield
    sanitizer.uninstall()


@handler(name="san_sink")
def _sink(ctx, obj):
    pass


# ---------------------------------------------------------------------------
# lock-order analysis (standalone sanitizer instances)
# ---------------------------------------------------------------------------

def test_seeded_lock_order_inversion_is_named():
    """Two threads taking A->B and B->A: a potential deadlock must be
    reported from the may-precede graph even though the run never
    actually deadlocked."""
    san = RuntimeSanitizer()
    a = san.tracked_lock("LockA")
    b = san.tracked_lock("LockB")

    def order(first, second):
        with first:
            with second:
                pass

    t1 = threading.Thread(target=order, args=(a, b), name="san-t1")
    t2 = threading.Thread(target=order, args=(b, a), name="san-t2")
    for t in (t1, t2):
        t.start()
        t.join()

    cycles = san.lock_order_cycles()
    assert cycles, san.lock_order_edges()
    assert set(cycles[0]) == {"LockA", "LockB"}
    with pytest.raises(SanitizerError, match="LockA.*LockB|LockB.*LockA"):
        san.check_lock_order()
    assert san.stats_snapshot()["potential_deadlocks"] >= 1


def test_consistent_order_and_trylock_are_clean():
    """A->B on both threads is fine; a trylock B-under-A then A-under-B
    adds no edge (trylocks cannot deadlock); same-name nesting adds no
    edge."""
    san = RuntimeSanitizer()
    a = san.tracked_lock("LockA")
    b = san.tracked_lock("LockB")
    a2 = san.tracked_lock("LockA")        # same NAME, distinct instance

    with a:
        with b:
            pass
        with a2:                          # same-name: excluded
            pass
    with b:
        assert a.acquire(blocking=False)  # trylock: no edge
        a.release()

    assert san.lock_order_cycles() == []
    assert ("LockB", "LockA") not in san.lock_order_edges()
    assert ("LockA", "LockA") not in san.lock_order_edges()
    assert ("LockA", "LockB") in san.lock_order_edges()


def test_rlock_proxy_supports_condition_wait():
    """Condition over a tracked RLock must round-trip wait/notify: the
    proxy delegates the private Condition protocol."""
    san = RuntimeSanitizer()
    lk = san.tracked_rlock("CondLock")
    cond = sanitizer.make_condition(lk)
    hit = []

    def waiter():
        with cond:
            while not hit:
                cond.wait(timeout=5.0)
            hit.append("seen")

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    with cond:
        hit.append("go")
        cond.notify_all()
    t.join(timeout=5.0)
    assert hit == ["go", "seen"]
    assert san.lock_order_cycles() == []


# ---------------------------------------------------------------------------
# lane discipline
# ---------------------------------------------------------------------------

def test_blocking_on_strict_lane_is_flagged_and_allowed_lane_is_not():
    san = RuntimeSanitizer()
    tok = san.enter_lane("net-send0", "net-send")     # strict
    san.note_future_wait(0.002)
    san.exit_lane(tok)
    tok = san.enter_lane("net-recv0", "net-recv")     # blocking-allowed
    san.note_future_wait(0.002)
    san.exit_lane(tok)
    san.note_future_wait(0.002)                       # not on a lane

    events = san.lane_blocking_report()
    assert len(events) == 1
    assert events[0]["kind"] == "net-send"
    assert events[0]["op"] == "future-wait"
    assert san.stats_snapshot()["lane_blocking_events"] == 1


def test_contended_lock_acquire_on_strict_lane_is_flagged():
    san = RuntimeSanitizer(block_threshold_s=0.005)
    lk = san.tracked_lock("Contended")
    lk.acquire()
    release_timer = threading.Timer(0.05, lk.release)
    release_timer.start()

    def job():
        tok = san.enter_lane("net-send0", "net-send")
        try:
            with lk:
                pass
        finally:
            san.exit_lane(tok)

    t = threading.Thread(target=job)
    t.start()
    t.join(timeout=5.0)
    release_timer.join()
    events = san.lane_blocking_report()
    assert any(e["op"] == "lock-acquire" and e["detail"] == "Contended"
               for e in events)


# ---------------------------------------------------------------------------
# distributed wait-for graph: seeded credit deadlock
# ---------------------------------------------------------------------------

class _BlackholeCTS(Cluster):
    """Drops every CTS: both directions' rendezvous streams park with
    zero credits — a seeded two-stream credit cycle."""

    def deliver(self, msg):
        if msg.kind == "cts":
            return
        super().deliver(msg)


def _wait(pred, timeout=10.0):
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return False


def test_blackholed_credit_cycle_named_and_gauges_raise(fresh_global_san):
    """Two opposite CTS-dropped streams: the wait-graph verdict names
    the rank cycle, the barrier timeout carries it, and shutdown raises
    a gauge-leak error naming the owning streams/peers."""
    cfg = RuntimeConfig(memory_capacity=1 << 28, eager_threshold=64 << 10,
                        chunk_bytes=128 << 10, sanitize=True)
    with pytest.raises(SanitizerError, match="leaked protocol state"):
        with _BlackholeCTS(2, cfg) as c:
            r0, r1 = c.ranks
            o0 = r0.runtime.hetero_object(np.ones(1 << 17, np.float32))
            o1 = r1.runtime.hetero_object(np.ones(1 << 17, np.float32))
            r0.send(1, "san_sink", o0)
            r1.send(0, "san_sink", o1)
            assert _wait(lambda: r0._rdzv_out and r1._rdzv_out)

            verdict = sanitizer.waitgraph_verdict(c)
            assert "potential deadlock cycle" in verdict
            assert "rank 0" in verdict and "rank 1" in verdict
            assert "credits" in verdict

            with pytest.raises(TimeoutError,
                               match="waitgraph: potential deadlock cycle"):
                c.barrier(timeout=1.0)

            san = sanitizer.current()
            assert san is not None
            assert san.stats_snapshot()["waitgraph_probes"] >= 2


def test_single_healthy_stream_is_not_a_cycle(fresh_global_san):
    """ONE stalled stream gives the trivial sender<->receiver 2-cycle on
    a single stream id — it must NOT be reported as a deadlock."""
    cfg = RuntimeConfig(memory_capacity=1 << 28, eager_threshold=64 << 10,
                        chunk_bytes=128 << 10, sanitize=True)
    try:
        with _BlackholeCTS(2, cfg) as c:
            r0 = c.ranks[0]
            obj = r0.runtime.hetero_object(np.ones(1 << 17, np.float32))
            r0.send(1, "san_sink", obj)
            assert _wait(lambda: bool(r0._rdzv_out))
            verdict = sanitizer.waitgraph_verdict(c)
            assert "potential deadlock cycle" not in verdict
            assert verdict.startswith("no cycle")
    except SanitizerError:
        pass          # expected at shutdown: the parked stream leaks


# ---------------------------------------------------------------------------
# clean runs: zero false positives
# ---------------------------------------------------------------------------

def test_clean_mini_workload_no_false_positives(fresh_global_san):
    """Sanitized end-to-end run (tasks + sends + barrier + shutdown):
    no deadlock report, no lane-blocking events, no gauge leaks."""
    cfg = RuntimeConfig(memory_capacity=1 << 28, eager_threshold=64 << 10,
                        chunk_bytes=128 << 10, sanitize=True)
    with Cluster(2, cfg) as c:
        r0 = c.ranks[0]
        for _ in range(3):
            obj = r0.runtime.hetero_object(
                np.random.default_rng(0).random(1 << 16).astype(np.float32))
            r0.send(1, "san_sink", obj)
        c.barrier()
        san = sanitizer.current()
        assert san is not None
        snap = san.stats_snapshot()
        assert snap["potential_deadlocks"] == 0, san.lock_order_cycles()
        assert snap["lane_blocking_events"] == 0, san.lane_blocking_report()
        assert snap["gauge_leaks"] == 0
        assert snap["lock_order_edges"] > 0       # tracking is actually on
        for r in c.ranks:
            assert r.runtime.stats()["sanitizer"] == snap
    # shutdown (gauge assertions armed) completed without raising


def test_clean_allreduce_no_false_positives(fresh_global_san):
    cfg = RuntimeConfig(memory_capacity=1 << 28, sanitize=True)
    with Cluster(2, cfg) as c:
        g = CollectiveGroup(c)
        ins = [np.full(4096, float(i + 1), np.float32) for i in range(2)]
        outs = g.allreduce(ins)
        for o in outs:
            np.testing.assert_allclose(np.asarray(o), 3.0)
        c.barrier()
        san = sanitizer.current()
        snap = san.stats_snapshot()
        assert snap["potential_deadlocks"] == 0, san.lock_order_cycles()
        assert snap["lane_blocking_events"] == 0, san.lane_blocking_report()
        # a completed collective leaves no pending ops in the wait graph
        assert not any(
            str(e[2]).startswith("coll-")
            for e in sanitizer.build_wait_graph(c).edges)


def test_stats_surface_off_by_default(fresh_global_san, monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    rt = Runtime(RuntimeConfig())
    try:
        assert "sanitizer" not in rt.stats()
    finally:
        rt.shutdown()


# ---------------------------------------------------------------------------
# clock injection (the fixed wall-clock findings stay fixed)
# ---------------------------------------------------------------------------

def test_barriers_consult_injectable_clock(fresh_global_san, monkeypatch):
    """Runtime.barrier and Cluster.barrier must read deadlines through
    repro.core.clock (regression: both used wall-clock time.time(),
    which jumps under NTP and breaks timeout math)."""
    calls = []
    real = clock.monotonic
    monkeypatch.setattr(clock, "monotonic", lambda: (calls.append(1),
                                                     real())[1])
    with Cluster(2, RuntimeConfig(memory_capacity=1 << 26)) as c:
        c.barrier()
    assert calls, "barrier never consulted the injectable clock"


def test_no_wallclock_calls_in_runtime_scope():
    """Static half of the same regression: R1 of the runtime lint finds
    zero time.time()/time.monotonic() calls in core/ + distributed/."""
    for scope in lint_runtime.SCOPE:
        for f in sorted((REPO / scope).glob("*.py")):
            rel = str(f.relative_to(REPO))
            chk = lint_runtime._Checker(rel, set(),
                                        f.read_text().splitlines())
            chk.visit(ast.parse(f.read_text()))
            r1 = [fd for fd in chk.findings if fd.rule == "R1"]
            assert not r1, [str(fd) for fd in r1]


# ---------------------------------------------------------------------------
# runtime lint: rule unit tests on synthetic sources + repo-clean gate
# ---------------------------------------------------------------------------

def _lint_src(src, path="src/repro/core/synthetic.py", registry=None):
    tree = ast.parse(src)
    reg = lint_runtime._Registry()
    reg.visit(tree)
    keys = reg.keys | (registry or set())
    chk = lint_runtime._Checker(path, keys, src.splitlines())
    chk.visit(tree)
    return chk.findings + lint_runtime.check_r4(chk)


def test_lint_r1_flags_wallclock_not_perf_counter():
    finds = _lint_src("import time\n"
                      "def f():\n"
                      "    a = time.time()\n"
                      "    b = time.monotonic()\n"
                      "    c = time.perf_counter()\n")
    assert sorted(f.rule for f in finds) == ["R1", "R1"]
    assert _lint_src("import time\nx = time.time()\n",
                     path="src/repro/core/clock.py") == []


def test_lint_r2_flags_raw_locks():
    finds = _lint_src("import threading\n"
                      "class A:\n"
                      "    def __init__(self):\n"
                      "        self._lock = threading.Lock()\n"
                      "        self._r = threading.RLock()\n")
    assert [f.rule for f in finds] == ["R2", "R2"]
    assert "make_lock" in finds[0].msg
    clean = _lint_src("from repro.core import sanitizer\n"
                      "class A:\n"
                      "    def __init__(self):\n"
                      "        self._lock = sanitizer.make_lock('A._lock')\n")
    assert clean == []


def test_lint_r3_requires_registered_stats_keys():
    src = ("class A:\n"
           "    def __init__(self):\n"
           "        self.stats = {'hits': 0}\n"
           "    def work(self):\n"
           "        self.stats['hits'] += 1\n"
           "        self.stats['ghost'] += 1\n")
    finds = _lint_src(src)
    assert [f.rule for f in finds] == ["R3"]
    assert "'ghost'" in finds[0].msg
    # registering the key in a stats() surface clears it
    fixed = src + ("    def stats(self):\n"
                   "        return {'ghost': self.stats['ghost']}\n")
    assert _lint_src(fixed) == []


def test_lint_r4_flags_blocking_in_lane_jobs_with_escape_hatch():
    src = ("def job(fut):\n"
           "    return fut.get()\n"
           "def go(lane, fut):\n"
           "    lane.submit(job)\n")
    finds = _lint_src(src)
    assert [f.rule for f in finds] == ["R4"]
    assert "fut.get()" in finds[0].msg
    escaped = ("def job(fut):\n"
               "    return fut.get()  # lint: allow-blocking\n"
               "def go(lane, fut):\n"
               "    lane.submit(job)\n")
    assert _lint_src(escaped) == []
    # one level of call-graph resolution: helper called from the job
    nested = ("def helper(fut):\n"
              "    fut.result()\n"
              "def job(fut):\n"
              "    helper(fut)\n"
              "def go(lane, fut):\n"
              "    lane.submit(lambda: job(None))\n")
    assert any(f.rule == "R4" for f in _lint_src(nested))


def test_repo_lint_is_clean():
    """The gate CI enforces: zero findings, zero stale allowlist
    entries on the committed tree."""
    assert lint_runtime.run() == 0
