"""Cross-pod gradient compression: numerics + convergence tracking."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compression import dequantize_int8, quantize_int8

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("shape", [(7,), (3, 300), (2, 5, 129), (256,)])
def test_quantize_roundtrip_error_bounded(shape):
    x = jax.random.normal(KEY, shape) * 3.0
    q, s, _ = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape, jnp.float32)
    # symmetric int8: error ≤ scale/2 = max|block|/254 per element
    err = jnp.abs(back - x)
    bound = jnp.max(jnp.abs(x)) / 127.0
    assert float(jnp.max(err)) <= float(bound) + 1e-6


def test_compressed_training_tracks_exact():
    """8 virtual devices, (pod=2, data=2, model=2): compressed-gradient
    training must track exact training closely (error feedback). Runs on
    every jax: native partial-manual shard_map when available, else the
    scan-over-pods compat formulation (same numerics, see
    train_step._compressed_grads)."""
    code = """
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import build_smoke
        from repro.models.sharding import use_sharding
        from repro.train import (TrainConfig, init_train_state,
                                 make_train_step, AdamWConfig)
        from repro.data import DataConfig, SyntheticLM
        cfg = get_smoke_config('yi_9b')
        m = build_smoke(cfg)
        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        opt = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=50)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8, seed=5))
        with use_sharding(mesh):
            s_ex = init_train_state(m, jax.random.PRNGKey(0))
            step_ex = jax.jit(make_train_step(m, TrainConfig(opt=opt)))
            s_cp = init_train_state(m, jax.random.PRNGKey(0), ef_pods=2)
            step_cp = jax.jit(make_train_step(
                m, TrainConfig(opt=opt, compress_pod_grads=True)))
            for i in range(6):
                b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
                s_ex, m_ex = step_ex(s_ex, b)
                s_cp, m_cp = step_cp(s_cp, b)
            d = max(float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - c.astype(jnp.float32))))
                for a, c in zip(jax.tree.leaves(s_ex.params),
                                jax.tree.leaves(s_cp.params)))
            assert d < 0.02, d
            assert abs(float(m_ex['loss']) - float(m_cp['loss'])) < 0.05
            print('compressed tracks exact, max param delta', d)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "compressed tracks exact" in out.stdout
