"""Message-protocol split (paper §4.2): eager vs rendezvous selection,
chunk-streamed reassembly integrity (in-order and out-of-order),
consumer-routed rendezvous landing, direct put/get routing, pool-buffer
recycling via the completion ack, and OwnerMap device hints."""
import threading
import time

import numpy as np
import pytest

from repro.core import RuntimeConfig
from repro.distributed import Cluster, OwnerMap, handler

_lock = threading.Lock()
_received = {}


@handler(name="proto_recv")
def _recv(ctx, obj):
    with _lock:
        _received["obj"] = obj
        _received["data"] = None if obj is None else obj.get()


@handler(name="proto_done")
def _done(ctx, obj):
    with _lock:
        _received["done"] = True


def _wait_for(key, timeout=20.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        with _lock:
            if key in _received:
                return True
        time.sleep(0.005)
    return False


@pytest.fixture()
def cluster():
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=128 << 10)
    with Cluster(2, cfg) as c:
        with _lock:
            _received.clear()
        yield c


# ---------------------------------------------------------------------------
# protocol selection
# ---------------------------------------------------------------------------

def test_small_payload_travels_eagerly(cluster):
    data = np.arange(1024, dtype=np.float32)        # 4 KB ≤ threshold
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    np.testing.assert_array_equal(_received["data"], data)
    assert cluster.ranks[0].stats["eager"] == 1
    assert cluster.ranks[0].stats["rendezvous"] == 0
    assert cluster.ranks[1].stats["chunks_in"] == 0


def test_large_payload_switches_to_rendezvous(cluster):
    data = np.arange(1 << 17, dtype=np.float32)     # 512 KB > threshold
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    np.testing.assert_array_equal(_received["data"], data)
    s = cluster.ranks[0].stats
    assert s["eager"] == 0 and s["rendezvous"] == 1
    assert s["chunks_out"] == 4                      # 512 KB / 128 KB
    assert cluster.ranks[1].stats["chunks_in"] == 4


def test_threshold_boundary_is_inclusive(cluster):
    data = np.zeros((64 << 10) // 4, np.float32)    # exactly threshold
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    assert cluster.ranks[0].stats["eager"] == 1
    assert cluster.ranks[0].stats["rendezvous"] == 0


# ---------------------------------------------------------------------------
# reassembly integrity
# ---------------------------------------------------------------------------

def test_chunked_reassembly_bit_exact_2d(cluster):
    rng = np.random.default_rng(7)
    data = rng.random((384, 384)).astype(np.float32)   # 576 KB, 5 chunks
    obj = cluster.ranks[0].runtime.hetero_object(data.copy())
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    np.testing.assert_array_equal(_received["data"], data)
    # the landed object is device-resident (pipelined upload), not host
    assert _received["obj"].resident_devices()


def test_uneven_tail_chunk_reassembles(cluster):
    # 300 KB: 2 full 128 KB chunks + one 44 KB tail
    data = np.arange((300 << 10) // 4, dtype=np.float32)
    obj = cluster.ranks[0].runtime.hetero_object(data.copy())
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    np.testing.assert_array_equal(_received["data"], data)
    assert cluster.ranks[0].stats["chunks_out"] == 3


class _ReorderingCluster(Cluster):
    """Delivers each rendezvous stream's chunks in REVERSE order — the
    out-of-order arrival a real network can produce."""

    def deliver(self, msg):
        if msg.kind == "chunk":
            held = self._held.setdefault(msg.msg_id, [])
            held.append(msg)
            if len(held) == msg.nchunks:
                for m in reversed(held):
                    super().deliver(m)
                del self._held[msg.msg_id]
            return
        super().deliver(msg)


def test_out_of_order_chunk_arrival_reassembles():
    # net_window=4 opens the whole stream's window up front: the
    # reordering cluster withholds every chunk until the last is sent,
    # which per-chunk credits would otherwise (correctly) never allow
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=128 << 10,
                        net_window=4)
    c = _ReorderingCluster.__new__(_ReorderingCluster)
    c._held = {}
    Cluster.__init__(c, 2, cfg)
    try:
        with _lock:
            _received.clear()
        data = np.arange(1 << 17, dtype=np.float32)    # 4 chunks
        obj = c.ranks[0].runtime.hetero_object(data.copy())
        c.ranks[0].send(1, "proto_recv", obj)
        assert _wait_for("data")
        np.testing.assert_array_equal(_received["data"], data)
        assert c.ranks[1].stats["chunks_in"] == 4
    finally:
        c.shutdown()


def test_cluster_barrier_covers_whole_rendezvous(cluster):
    """Regression: barrier() must not return between the last chunk's
    dequeue and the handler invocation (reassembly state lives in
    _rdzv_in until the handler ran; the pump flags itself active while
    extracting work)."""
    for trial in range(3):
        with _lock:
            _received.clear()
        data = np.arange(1 << 17, dtype=np.float32) + trial
        obj = cluster.ranks[0].runtime.hetero_object(data)
        cluster.ranks[0].send(1, "proto_recv", obj)
        cluster.barrier()
        with _lock:
            assert "data" in _received, f"trial {trial}: barrier early"
            np.testing.assert_array_equal(_received["data"], data)


# ---------------------------------------------------------------------------
# consumer routing on the rendezvous path
# ---------------------------------------------------------------------------

def test_rendezvous_lands_on_consumer_device(cluster):
    rt1 = cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    data = np.arange(1 << 17, dtype=np.float32)
    rt0 = cluster.ranks[0].runtime
    obj = rt0.hetero_object(data)
    rt0.run(lambda v: v + 1.0, [(obj, "rw")])
    rt0.barrier()
    cluster.ranks[0].send(1, "proto_recv", obj, path="direct",
                          consumer_device=1)
    assert _wait_for("obj")
    assert _received["obj"].resident_devices() == {1}
    np.testing.assert_allclose(_received["data"], data + 1.0)
    assert cluster.ranks[0].stats["rendezvous"] == 1


def test_rendezvous_respects_route_to(cluster):
    rt1 = cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    cluster.ranks[1].route_to("proto_recv", 1)
    try:
        data = np.ones(1 << 17, np.float32)
        obj = cluster.ranks[0].runtime.hetero_object(data)
        cluster.ranks[0].send(1, "proto_recv", obj)   # host-staged rdzv
        assert _wait_for("obj")
        assert _received["obj"].resident_devices() == {1}
    finally:
        cluster.ranks[1].routes.clear()


# ---------------------------------------------------------------------------
# staging-pool recycle via the completion ack
# ---------------------------------------------------------------------------

def test_ack_returns_streamed_buffer_to_pool(cluster):
    data = np.ones(1 << 17, np.float32)
    r0 = cluster.ranks[0]
    obj = r0.runtime.hetero_object(data)
    r0.send(1, "proto_recv", obj)
    assert _wait_for("data")
    deadline = time.time() + 10
    while r0._rdzv_bufs and time.time() < deadline:
        time.sleep(0.005)
    assert not r0._rdzv_bufs          # ack arrived, buffer released
    hits0 = r0.runtime.staging.hits
    with _lock:
        _received.clear()
    obj2 = r0.runtime.hetero_object(data * 2)
    r0.send(1, "proto_recv", obj2)    # same shape: pool must hit
    assert _wait_for("data")
    assert r0.runtime.staging.hits > hits0


# ---------------------------------------------------------------------------
# consumer-routed put/get (ROADMAP follow-up d)
# ---------------------------------------------------------------------------

def test_direct_put_lands_device_resident_no_host_staging(cluster):
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    target = rt1.hetero_object(np.zeros((64, 64), np.float32))
    cluster.ranks[1].register_object("tgt", target)
    src = rt0.hetero_object(np.full((64, 64), 3.0, np.float32))
    rt0.run(lambda v: v * 2.0, [(src, "rw")])   # leaves a device copy
    rt0.barrier()
    staged0 = cluster.ranks[1].stats["bytes_staged"]
    cluster.ranks[0].put(1, "tgt", src, on_done="proto_done",
                         path="direct", consumer_device=1)
    assert _wait_for("done")
    assert target.resident_devices() == {1}
    np.testing.assert_allclose(target.get(), 6.0)
    assert cluster.ranks[1].stats["bytes_staged"] == staged0
    assert cluster.ranks[1].stats["bytes_d2d"] >= src.nbytes


def test_direct_put_host_only_degrades_to_staged(cluster):
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    target = rt1.hetero_object(np.zeros((32,), np.float32))
    cluster.ranks[1].register_object("tgt2", target)
    src = rt0.hetero_object(np.full((32,), 5.0, np.float32))
    cluster.ranks[0].put(1, "tgt2", src, on_done="proto_done",
                         path="direct")
    assert _wait_for("done")
    np.testing.assert_allclose(target.get(), 5.0)


def test_get_reply_is_consumer_routed(cluster):
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    if len(rt0.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    remote = rt1.hetero_object(np.full((64, 64), 4.0, np.float32))
    rt1.run(lambda v: v + 1.0, [(remote, "rw")])   # device copy on owner
    rt1.barrier()
    cluster.ranks[1].register_object("src", remote)
    cluster.ranks[0].get(1, "src", "proto_recv", path="direct",
                         consumer_device=1)
    assert _wait_for("obj")
    assert _received["obj"].resident_devices() == {1}
    np.testing.assert_allclose(_received["data"], 5.0)


# ---------------------------------------------------------------------------
# oversized put travels the rendezvous path (ROADMAP follow-up b)
# ---------------------------------------------------------------------------

def test_oversized_put_chunk_streams_through_rendezvous(cluster):
    r0, r1 = cluster.ranks
    target = r1.runtime.hetero_object(
        np.zeros((1 << 17,), np.float32))            # 512 KB > threshold
    r1.register_object("big_tgt", target)
    data = np.arange(1 << 17, dtype=np.float32)
    src = r0.runtime.hetero_object(data.copy())
    r0.put(1, "big_tgt", src, on_done="proto_done")
    assert _wait_for("done")
    cluster.barrier()
    np.testing.assert_array_equal(target.get(), data)
    s = r0.stats
    assert s["rendezvous"] == 1, s                   # not a monolithic put
    assert s["chunks_out"] == 4                      # 512 KB / 128 KB
    assert cluster.ranks[1].stats["chunks_in"] == 4


def test_oversized_put_recycles_pooled_buffer_on_ack(cluster):
    r0, r1 = cluster.ranks
    target = r1.runtime.hetero_object(np.zeros((1 << 17,), np.float32))
    r1.register_object("big_tgt2", target)
    src = r0.runtime.hetero_object(np.ones((1 << 17,), np.float32))
    r0.put(1, "big_tgt2", src, on_done="proto_done")
    assert _wait_for("done")
    cluster.barrier()
    deadline = time.time() + 10
    while r0._rdzv_bufs and time.time() < deadline:
        time.sleep(0.005)
    assert not r0._rdzv_bufs          # ack arrived, buffer released
    np.testing.assert_allclose(target.get(), 1.0)


def test_small_put_stays_eager(cluster):
    r0, r1 = cluster.ranks
    target = r1.runtime.hetero_object(np.zeros((32,), np.float32))
    r1.register_object("small_tgt", target)
    src = r0.runtime.hetero_object(np.full((32,), 7.0, np.float32))
    r0.put(1, "small_tgt", src, on_done="proto_done")
    assert _wait_for("done")
    assert r0.stats["rendezvous"] == 0
    np.testing.assert_allclose(target.get(), 7.0)


def test_oversized_direct_put_lands_consumer_routed(cluster):
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    target = rt1.hetero_object(np.zeros((1 << 17,), np.float32))
    cluster.ranks[1].register_object("big_tgt3", target)
    src = rt0.hetero_object(np.full((1 << 17,), 3.0, np.float32))
    rt0.run(lambda v: v * 2.0, [(src, "rw")])   # leaves a device copy
    rt0.barrier()
    staged0 = cluster.ranks[1].stats["bytes_staged"]
    cluster.ranks[0].put(1, "big_tgt3", src, on_done="proto_done",
                         path="direct", consumer_device=1)
    assert _wait_for("done")
    cluster.barrier()
    assert target.resident_devices() == {1}
    np.testing.assert_allclose(target.get(), 6.0)
    assert cluster.ranks[0].stats["rendezvous"] == 1
    assert cluster.ranks[1].stats["bytes_staged"] == staged0


# ---------------------------------------------------------------------------
# OwnerMap device hints
# ---------------------------------------------------------------------------

def test_ownermap_carries_device_hints():
    om = OwnerMap()
    om.assign(0, rank=1, device_hint=3)
    om.assign(1, rank=1)
    assert om.device_hint(0) == 3
    assert om.device_hint(1) is None
    v = om.version
    om.set_device_hint(1, 2)
    assert om.device_hint(1) == 2 and om.version == v + 1
    # migration without a fresh hint clears the stale one (device ids are
    # local to the previous owner)
    om.migrate(0, new_rank=0)
    assert om.device_hint(0) is None
    om.migrate(1, new_rank=0, device_hint=1)
    assert om.device_hint(1) == 1
    om.set_device_hint(1, None)
    assert om.device_hint(1) is None
