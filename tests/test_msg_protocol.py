"""Message-protocol split (paper §4.2): eager vs rendezvous selection,
chunk-streamed reassembly integrity (in-order and out-of-order),
consumer-routed rendezvous landing, direct put/get routing, pool-buffer
recycling via the completion ack, and OwnerMap device hints."""
import threading
import time

import numpy as np
import pytest

from repro.core import RuntimeConfig, sanitizer
from repro.distributed import Cluster, OwnerMap, handler

_lock = threading.Lock()
_received = {}


@handler(name="proto_recv")
def _recv(ctx, obj):
    with _lock:
        _received["obj"] = obj
        _received["data"] = None if obj is None else obj.get()


@handler(name="proto_done")
def _done(ctx, obj):
    with _lock:
        _received["done"] = True


def _wait_for(key, timeout=20.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        with _lock:
            if key in _received:
                return True
        time.sleep(0.005)
    return False


@pytest.fixture()
def cluster():
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=128 << 10)
    with Cluster(2, cfg) as c:
        with _lock:
            _received.clear()
        yield c


# ---------------------------------------------------------------------------
# protocol selection
# ---------------------------------------------------------------------------

def test_small_payload_travels_eagerly(cluster):
    data = np.arange(1024, dtype=np.float32)        # 4 KB ≤ threshold
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    np.testing.assert_array_equal(_received["data"], data)
    assert cluster.ranks[0].stats["eager"] == 1
    assert cluster.ranks[0].stats["rendezvous"] == 0
    assert cluster.ranks[1].stats["chunks_in"] == 0


def test_large_payload_switches_to_rendezvous(cluster):
    data = np.arange(1 << 17, dtype=np.float32)     # 512 KB > threshold
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    np.testing.assert_array_equal(_received["data"], data)
    s = cluster.ranks[0].stats
    assert s["eager"] == 0 and s["rendezvous"] == 1
    assert s["chunks_out"] == 4                      # 512 KB / 128 KB
    assert cluster.ranks[1].stats["chunks_in"] == 4


def test_threshold_boundary_is_inclusive(cluster):
    data = np.zeros((64 << 10) // 4, np.float32)    # exactly threshold
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    assert cluster.ranks[0].stats["eager"] == 1
    assert cluster.ranks[0].stats["rendezvous"] == 0


# ---------------------------------------------------------------------------
# reassembly integrity
# ---------------------------------------------------------------------------

def test_chunked_reassembly_bit_exact_2d(cluster):
    rng = np.random.default_rng(7)
    data = rng.random((384, 384)).astype(np.float32)   # 576 KB, 5 chunks
    obj = cluster.ranks[0].runtime.hetero_object(data.copy())
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    np.testing.assert_array_equal(_received["data"], data)
    # the landed object is device-resident (pipelined upload), not host
    assert _received["obj"].resident_devices()


def test_uneven_tail_chunk_reassembles(cluster):
    # 300 KB: 2 full 128 KB chunks + one 44 KB tail
    data = np.arange((300 << 10) // 4, dtype=np.float32)
    obj = cluster.ranks[0].runtime.hetero_object(data.copy())
    cluster.ranks[0].send(1, "proto_recv", obj)
    assert _wait_for("data")
    np.testing.assert_array_equal(_received["data"], data)
    assert cluster.ranks[0].stats["chunks_out"] == 3


class _ReorderingCluster(Cluster):
    """Delivers each rendezvous stream's chunks in REVERSE order — the
    out-of-order arrival a real network can produce."""

    def deliver(self, msg):
        if msg.kind == "chunk":
            held = self._held.setdefault(msg.msg_id, [])
            held.append(msg)
            if len(held) == msg.nchunks:
                for m in reversed(held):
                    super().deliver(m)
                del self._held[msg.msg_id]
            return
        super().deliver(msg)


def test_out_of_order_chunk_arrival_reassembles():
    # net_window=4 opens the whole stream's window up front: the
    # reordering cluster withholds every chunk until the last is sent,
    # which per-chunk credits would otherwise (correctly) never allow
    cfg = RuntimeConfig(memory_capacity=1 << 28,
                        eager_threshold=64 << 10, chunk_bytes=128 << 10,
                        net_window=4)
    c = _ReorderingCluster.__new__(_ReorderingCluster)
    c._held = {}
    Cluster.__init__(c, 2, cfg)
    try:
        with _lock:
            _received.clear()
        data = np.arange(1 << 17, dtype=np.float32)    # 4 chunks
        obj = c.ranks[0].runtime.hetero_object(data.copy())
        c.ranks[0].send(1, "proto_recv", obj)
        assert _wait_for("data")
        np.testing.assert_array_equal(_received["data"], data)
        assert c.ranks[1].stats["chunks_in"] == 4
    finally:
        c.shutdown()


def test_cluster_barrier_covers_whole_rendezvous(cluster):
    """Regression: barrier() must not return between the last chunk's
    dequeue and the handler invocation (reassembly state lives in
    _rdzv_in until the handler ran; the pump flags itself active while
    extracting work)."""
    for trial in range(3):
        with _lock:
            _received.clear()
        data = np.arange(1 << 17, dtype=np.float32) + trial
        obj = cluster.ranks[0].runtime.hetero_object(data)
        cluster.ranks[0].send(1, "proto_recv", obj)
        cluster.barrier()
        with _lock:
            assert "data" in _received, f"trial {trial}: barrier early"
            np.testing.assert_array_equal(_received["data"], data)


# ---------------------------------------------------------------------------
# consumer routing on the rendezvous path
# ---------------------------------------------------------------------------

def test_rendezvous_lands_on_consumer_device(cluster):
    rt1 = cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    data = np.arange(1 << 17, dtype=np.float32)
    rt0 = cluster.ranks[0].runtime
    obj = rt0.hetero_object(data)
    rt0.run(lambda v: v + 1.0, [(obj, "rw")])
    rt0.barrier()
    cluster.ranks[0].send(1, "proto_recv", obj, path="direct",
                          consumer_device=1)
    assert _wait_for("obj")
    assert _received["obj"].resident_devices() == {1}
    np.testing.assert_allclose(_received["data"], data + 1.0)
    assert cluster.ranks[0].stats["rendezvous"] == 1


def test_rendezvous_respects_route_to(cluster):
    rt1 = cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    cluster.ranks[1].route_to("proto_recv", 1)
    try:
        data = np.ones(1 << 17, np.float32)
        obj = cluster.ranks[0].runtime.hetero_object(data)
        cluster.ranks[0].send(1, "proto_recv", obj)   # host-staged rdzv
        assert _wait_for("obj")
        assert _received["obj"].resident_devices() == {1}
    finally:
        cluster.ranks[1].routes.clear()


# ---------------------------------------------------------------------------
# staging-pool recycle via the completion ack
# ---------------------------------------------------------------------------

def test_ack_returns_streamed_buffer_to_pool(cluster):
    data = np.ones(1 << 17, np.float32)
    r0 = cluster.ranks[0]
    obj = r0.runtime.hetero_object(data)
    r0.send(1, "proto_recv", obj)
    assert _wait_for("data")
    deadline = time.time() + 10
    while r0._rdzv_bufs and time.time() < deadline:
        time.sleep(0.005)
    assert not r0._rdzv_bufs          # ack arrived, buffer released
    hits0 = r0.runtime.staging.hits
    with _lock:
        _received.clear()
    obj2 = r0.runtime.hetero_object(data * 2)
    r0.send(1, "proto_recv", obj2)    # same shape: pool must hit
    assert _wait_for("data")
    assert r0.runtime.staging.hits > hits0


# ---------------------------------------------------------------------------
# consumer-routed put/get (ROADMAP follow-up d)
# ---------------------------------------------------------------------------

def test_direct_put_lands_device_resident_no_host_staging(cluster):
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    target = rt1.hetero_object(np.zeros((64, 64), np.float32))
    cluster.ranks[1].register_object("tgt", target)
    src = rt0.hetero_object(np.full((64, 64), 3.0, np.float32))
    rt0.run(lambda v: v * 2.0, [(src, "rw")])   # leaves a device copy
    rt0.barrier()
    staged0 = cluster.ranks[1].stats["bytes_staged"]
    cluster.ranks[0].put(1, "tgt", src, on_done="proto_done",
                         path="direct", consumer_device=1)
    assert _wait_for("done")
    assert target.resident_devices() == {1}
    np.testing.assert_allclose(target.get(), 6.0)
    assert cluster.ranks[1].stats["bytes_staged"] == staged0
    assert cluster.ranks[1].stats["bytes_d2d"] >= src.nbytes


def test_direct_put_host_only_degrades_to_staged(cluster):
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    target = rt1.hetero_object(np.zeros((32,), np.float32))
    cluster.ranks[1].register_object("tgt2", target)
    src = rt0.hetero_object(np.full((32,), 5.0, np.float32))
    cluster.ranks[0].put(1, "tgt2", src, on_done="proto_done",
                         path="direct")
    assert _wait_for("done")
    np.testing.assert_allclose(target.get(), 5.0)


def test_get_reply_is_consumer_routed(cluster):
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    if len(rt0.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    remote = rt1.hetero_object(np.full((64, 64), 4.0, np.float32))
    rt1.run(lambda v: v + 1.0, [(remote, "rw")])   # device copy on owner
    rt1.barrier()
    cluster.ranks[1].register_object("src", remote)
    cluster.ranks[0].get(1, "src", "proto_recv", path="direct",
                         consumer_device=1)
    assert _wait_for("obj")
    assert _received["obj"].resident_devices() == {1}
    np.testing.assert_allclose(_received["data"], 5.0)


# ---------------------------------------------------------------------------
# oversized put travels the rendezvous path (ROADMAP follow-up b)
# ---------------------------------------------------------------------------

def test_oversized_put_chunk_streams_through_rendezvous(cluster):
    r0, r1 = cluster.ranks
    target = r1.runtime.hetero_object(
        np.zeros((1 << 17,), np.float32))            # 512 KB > threshold
    r1.register_object("big_tgt", target)
    data = np.arange(1 << 17, dtype=np.float32)
    src = r0.runtime.hetero_object(data.copy())
    r0.put(1, "big_tgt", src, on_done="proto_done")
    assert _wait_for("done")
    cluster.barrier()
    np.testing.assert_array_equal(target.get(), data)
    s = r0.stats
    assert s["rendezvous"] == 1, s                   # not a monolithic put
    assert s["chunks_out"] == 4                      # 512 KB / 128 KB
    assert cluster.ranks[1].stats["chunks_in"] == 4


def test_oversized_put_recycles_pooled_buffer_on_ack(cluster):
    r0, r1 = cluster.ranks
    target = r1.runtime.hetero_object(np.zeros((1 << 17,), np.float32))
    r1.register_object("big_tgt2", target)
    src = r0.runtime.hetero_object(np.ones((1 << 17,), np.float32))
    r0.put(1, "big_tgt2", src, on_done="proto_done")
    assert _wait_for("done")
    cluster.barrier()
    deadline = time.time() + 10
    while r0._rdzv_bufs and time.time() < deadline:
        time.sleep(0.005)
    assert not r0._rdzv_bufs          # ack arrived, buffer released
    np.testing.assert_allclose(target.get(), 1.0)


def test_small_put_stays_eager(cluster):
    r0, r1 = cluster.ranks
    target = r1.runtime.hetero_object(np.zeros((32,), np.float32))
    r1.register_object("small_tgt", target)
    src = r0.runtime.hetero_object(np.full((32,), 7.0, np.float32))
    r0.put(1, "small_tgt", src, on_done="proto_done")
    assert _wait_for("done")
    assert r0.stats["rendezvous"] == 0
    np.testing.assert_allclose(target.get(), 7.0)


def test_oversized_direct_put_lands_consumer_routed(cluster):
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    target = rt1.hetero_object(np.zeros((1 << 17,), np.float32))
    cluster.ranks[1].register_object("big_tgt3", target)
    src = rt0.hetero_object(np.full((1 << 17,), 3.0, np.float32))
    rt0.run(lambda v: v * 2.0, [(src, "rw")])   # leaves a device copy
    rt0.barrier()
    staged0 = cluster.ranks[1].stats["bytes_staged"]
    cluster.ranks[0].put(1, "big_tgt3", src, on_done="proto_done",
                         path="direct", consumer_device=1)
    assert _wait_for("done")
    cluster.barrier()
    assert target.resident_devices() == {1}
    np.testing.assert_allclose(target.get(), 6.0)
    assert cluster.ranks[0].stats["rendezvous"] == 1
    assert cluster.ranks[1].stats["bytes_staged"] == staged0


# ---------------------------------------------------------------------------
# Lane.busy() window regression: barrier never returns mid-delivery
# ---------------------------------------------------------------------------

def test_cluster_barrier_never_early_200_iterations(cluster):
    """Acceptance: 200 consecutive send→barrier cycles, each a 2-chunk
    rendezvous stream, must all observe the handler's effects after
    barrier(). The popped-but-unmarked Lane window (a job extracted from
    the net-recv/net-send queue but not yet marked executing) made the
    two-sweep all-idle check porous; the pending-counter accounting
    closes it."""
    data = np.arange((256 << 10) // 4, dtype=np.float32)     # 2 chunks
    for trial in range(200):
        with _lock:
            _received.clear()
        obj = cluster.ranks[0].runtime.hetero_object(data + trial)
        cluster.ranks[0].send(1, "proto_recv", obj)
        cluster.barrier()
        with _lock:
            assert "data" in _received, f"trial {trial}: barrier early"
            np.testing.assert_array_equal(_received["data"], data + trial)


# ---------------------------------------------------------------------------
# swallowed handler errors → error sink (strict mode)
# ---------------------------------------------------------------------------

@handler(name="proto_boom")
def _boom(ctx, obj):
    raise ValueError("handler exploded")


def test_handler_error_routed_to_sink():
    cfg = RuntimeConfig(memory_capacity=1 << 26, eager_threshold=64 << 10)
    with Cluster(2, cfg) as c:
        c.ranks[0].send(1, "proto_boom")
        deadline = time.time() + 10
        r1 = c.ranks[1]
        while r1.stats["handler_errors"] == 0 and time.time() < deadline:
            time.sleep(0.005)
        assert r1.stats["handler_errors"] == 1
        c.barrier()            # not strict: barrier passes, error counted


def test_handler_error_strict_mode_fails_barrier():
    cfg = RuntimeConfig(memory_capacity=1 << 26, eager_threshold=64 << 10,
                        strict_errors=True)
    with Cluster(2, cfg) as c:
        c.ranks[0].send(1, "proto_boom")
        deadline = time.time() + 10
        while c.ranks[1].stats["handler_errors"] == 0 \
                and time.time() < deadline:
            time.sleep(0.005)
        with pytest.raises(RuntimeError, match="handler error"):
            c.barrier()


# ---------------------------------------------------------------------------
# rendezvous-state hygiene: peer loss / shutdown sweeps (leak gauges)
# ---------------------------------------------------------------------------

class _BlackholeCluster(Cluster):
    """Drops selected message kinds toward selected ranks — the
    mid-stream peer loss an elastic rescale produces."""

    def deliver(self, msg):
        if msg.kind in self._drop_kinds and msg.dst in self._drop_to:
            return
        super().deliver(msg)


def _blackhole(n, cfg, drop_kinds, drop_to):
    c = _BlackholeCluster.__new__(_BlackholeCluster)
    c._drop_kinds = frozenset(drop_kinds)
    c._drop_to = frozenset(drop_to)
    Cluster.__init__(c, n, cfg)
    return c


def test_remove_peer_sweeps_parked_stream_and_releases_buffer():
    """Peer lost before its CTS arrives: the outgoing stream state (and
    its pooled staging buffer) must be swept by remove_peer, and the
    buffer must genuinely return to the pool (next same-shape acquire
    hits)."""
    cfg = RuntimeConfig(memory_capacity=1 << 28, eager_threshold=64 << 10,
                        chunk_bytes=128 << 10)
    with _blackhole(2, cfg, drop_kinds={"cts"}, drop_to={0}) as c:
        r0 = c.ranks[0]
        obj = r0.runtime.hetero_object(np.ones(1 << 17, np.float32))
        r0.send(1, "proto_recv", obj)
        deadline = time.time() + 10
        while not r0._rdzv_out and time.time() < deadline:
            time.sleep(0.005)
        assert r0.state_gauges()["rdzv_out"] == 1    # parked, CTS lost
        hits0 = r0.runtime.staging.hits
        swept = r0.remove_peer(1)
        assert swept["rdzv_out"] == 1
        gauges = r0.state_gauges()
        assert all(v == 0 for v in gauges.values()), gauges
        # the pooled staging buffer is back: same-shape acquire hits
        buf = r0.runtime.staging.acquire((1 << 17,), np.float32)
        assert r0.runtime.staging.hits == hits0 + 1
        r0.runtime.staging.release(buf)
        # the receiver's reassembly state for the never-completed stream
        # is stranded too — reclaim it the same way (found by the
        # sanitizer's shutdown gauge check: rdzv_in leaked on rank 1)
        r1 = c.ranks[1]
        swept1 = r1.remove_peer(0)
        assert swept1["rdzv_in"] == 1
        assert all(v == 0 for v in r1.state_gauges().values())


def test_remove_peer_sweeps_ack_parked_buffer_and_receiver_state():
    """Stream fully sent but the completion ack is lost: the sender's
    parked pool buffer leaks until the peer-removal sweep. The receiver
    side sweeps its reassembly state for the removed peer too."""
    cfg = RuntimeConfig(memory_capacity=1 << 28, eager_threshold=64 << 10,
                        chunk_bytes=128 << 10)
    with _blackhole(2, cfg, drop_kinds={"ack"}, drop_to={0}) as c:
        r0, r1 = c.ranks
        with _lock:
            _received.clear()
        obj = r0.runtime.hetero_object(np.ones(1 << 17, np.float32))
        r0.send(1, "proto_recv", obj)
        assert _wait_for("data")
        c.barrier()
        assert r0.state_gauges()["rdzv_bufs"] == 1   # ack never came
        swept = r0.remove_peer(1)
        assert swept["rdzv_bufs"] == 1
        assert all(v == 0 for v in r0.state_gauges().values())
        # receiver-side sweep: orphaned reassembly state from a lost peer
        r1._rdzv_in[999] = {"meta": type("M", (), {"src": 0,
                                                   "total_bytes": 0})()}
        r1._pending_meta[998] = type("M", (), {"src": 0})()
        swept1 = r1.remove_peer(0)
        assert swept1["rdzv_in"] == 1 and swept1["pending_meta"] == 1
        assert all(v == 0 for v in r1.state_gauges().values())


def test_shutdown_sweeps_all_rendezvous_state():
    cfg = RuntimeConfig(memory_capacity=1 << 28, eager_threshold=64 << 10,
                        chunk_bytes=128 << 10)
    c = _blackhole(2, cfg, drop_kinds={"cts", "ack"}, drop_to={0})
    try:
        r0 = c.ranks[0]
        obj = r0.runtime.hetero_object(np.ones(1 << 17, np.float32))
        r0.send(1, "proto_recv", obj)
        deadline = time.time() + 10
        while not r0._rdzv_out and time.time() < deadline:
            time.sleep(0.005)
        assert r0.state_gauges()["rdzv_out"] == 1
    finally:
        try:
            c.shutdown()
        except sanitizer.SanitizerError as e:
            # under REPRO_SANITIZE=1 the shutdown gauge check correctly
            # flags the deliberately stranded stream; teardown (and the
            # sweeps this test verifies) still completed first
            assert "leaked protocol state" in str(e)
    assert all(v == 0 for v in c.ranks[0].state_gauges().values())
    assert all(v == 0 for v in c.ranks[1].state_gauges().values())


# ---------------------------------------------------------------------------
# adaptive flow-control edge cases (review regressions)
# ---------------------------------------------------------------------------

def test_slab_occupancy_excludes_deciding_stream(cluster):
    """A stream's own fully-committed slab must not count toward its own
    congestion signal — a single transfer larger than WINDOW_SLAB_LIMIT
    would otherwise collapse its own window to 1 for its whole life."""
    r1 = cluster.ranks[1]

    class _Meta:
        def __init__(self, nb):
            self.total_bytes = nb
            self.src = 0
    r1._rdzv_in[101] = {"meta": _Meta(64 << 20)}
    r1._rdzv_in[102] = {"meta": _Meta(8 << 20)}
    try:
        assert r1._slab_bytes() == (64 << 20) + (8 << 20)
        assert r1._slab_bytes(exclude_mid=101) == 8 << 20
        assert r1._slab_bytes(exclude_mid=102) == 64 << 20
    finally:
        r1._rdzv_in.clear()


def test_stale_reordered_credit_cannot_rewiden_window(cluster):
    """Control VCs can reorder: a credit carrying an older acked count
    must not overwrite the window target of a newer one the receiver
    shrank (window accepted only when acked advances)."""
    r0 = cluster.ranks[0]

    class _Meta:
        nchunks = 1000   # far from exhausted: stream state stays parked
        dst = 1
        path = "host"
    state = {"meta": _Meta(), "flat": np.zeros(32, np.float32),
             "arr": None, "elems": 1, "pooled": False, "next_seq": 0,
             "credits": 0, "window": None, "acked": 0}
    r0._rdzv_out[777] = state
    try:
        # CTS opens window 8
        r0._advance_stream(777, 8, window=8, acked=0, initial=True)
        assert state["window"] == 8
        # newer credit shrinks to 2 (acked advances to 5)
        r0._advance_stream(777, 1, window=2, acked=5)
        assert state["window"] == 2 and state["acked"] == 5
        # stale reordered credit (acked 3, window 8) must change nothing
        r0._advance_stream(777, 1, window=8, acked=3)
        assert state["window"] == 2 and state["acked"] == 5
        # next genuinely-newer credit is accepted again
        r0._advance_stream(777, 1, window=3, acked=6)
        assert state["window"] == 3 and state["acked"] == 6
    finally:
        r0._rdzv_out.pop(777, None)


# ---------------------------------------------------------------------------
# OwnerMap device hints
# ---------------------------------------------------------------------------

def test_ownermap_carries_device_hints():
    om = OwnerMap()
    om.assign(0, rank=1, device_hint=3)
    om.assign(1, rank=1)
    assert om.device_hint(0) == 3
    assert om.device_hint(1) is None
    v = om.version
    om.set_device_hint(1, 2)
    assert om.device_hint(1) == 2 and om.version == v + 1
    # migration without a fresh hint clears the stale one (device ids are
    # local to the previous owner)
    om.migrate(0, new_rank=0)
    assert om.device_hint(0) is None
    om.migrate(1, new_rank=0, device_hint=1)
    assert om.device_hint(1) == 1
    om.set_device_hint(1, None)
    assert om.device_hint(1) is None
