"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU; asserts output shapes and no NaNs. Also checks
prefill+decode consistency against the full forward (the serving invariant).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, shapes_for
from repro.models import build_smoke
from repro.models.layers import unbox
from repro.train import TrainConfig, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b, s, key=KEY):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            key, (b, cfg.frontend_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    m = build_smoke(cfg)
    params, _ = unbox(m.init(KEY))
    b, s = 2, 64
    batch = _batch(cfg, b, s)
    x, cache, aux = m.apply(params, batch, mode="train")
    assert x.shape == (b, s, cfg.d_model)
    assert not bool(jnp.isnan(x).any())
    logits = m.unembed(params, x[:, -2:])
    assert logits.shape == (b, 2, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    m = build_smoke(cfg)
    state = init_train_state(m, KEY)
    step = make_train_step(m, TrainConfig())
    batch = _batch(cfg, 2, 32)
    new_state, metrics = jax.jit(step)(state, batch)
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert int(new_state.opt.step) == 1
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(new_state.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    m = build_smoke(cfg)
    params, _ = unbox(m.init(KEY))
    B, S = 2, 66
    batch = _batch(cfg, B, S)
    x_full, _, _ = m.apply(params, dict(batch), mode="train")
    logits_full = m.unembed(params, x_full)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    pre.pop("labels")
    cache0 = m.init_cache(B, S - 1)
    _, cache, _ = m.apply(params, pre, mode="prefill", cache=cache0)

    def pad_seq(a):
        if not hasattr(a, "ndim") or a.ndim < 2:
            return a
        for ax in range(1, min(a.ndim, 3)):
            if a.shape[ax] == S - 1 and a.shape[-1] != S - 1:
                pw = [(0, 0)] * a.ndim
                pw[ax] = (0, 1)
                return jnp.pad(a, pw)
        return a

    cache = jax.tree.map(pad_seq, cache)
    dec = {"tokens": batch["tokens"][:, S - 1:S],
           "lengths": jnp.full((B,), S - 1, jnp.int32)}
    x_dec, _, _ = m.apply(params, dec, mode="decode", cache=cache)
    logits_dec = m.unembed(params, x_dec)[:, 0]
    err = float(jnp.max(jnp.abs(logits_dec - logits_full[:, -1])))
    assert err < 2e-2, f"{arch}: decode diverges from forward by {err}"


def test_full_configs_match_published_sizes():
    """Exact published configs instantiate (abstractly) with the right
    parameter counts — never allocated on CPU."""
    expected_b = {
        "recurrentgemma_9b": (8.5, 11), "gemma3_27b": (26, 30),
        "phi4_mini_3_8b": (3.5, 4.2), "codeqwen15_7b": (6.5, 8.9),
        "yi_9b": (8.2, 9.5), "pixtral_12b": (11.5, 13),
        "whisper_large_v3": (1.4, 1.8), "mamba2_370m": (0.3, 0.45),
        "llama4_scout_17b_a16e": (100, 115), "olmoe_1b_7b": (6.3, 7.5),
    }
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        lo, hi = expected_b[arch]
        n = cfg.param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    cfg = get_config("llama4_scout_17b_a16e")
    assert 15e9 <= cfg.active_param_count() <= 19e9
    cfg = get_config("olmoe_1b_7b")
    assert 0.9e9 <= cfg.active_param_count() <= 1.6e9


def test_shape_grid_covers_40_cells():
    """10 archs × 4 shapes nominal; skipped long_500k cells are documented."""
    total = 0
    skipped = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = shapes_for(cfg)
        total += len(shapes)
        skipped += 4 - len(shapes)
    assert total + skipped == 40
    assert skipped == 7  # 7 pure-full-attention archs skip long_500k
