"""End-to-end LM training driver example.

Default: a ~15M-parameter gemma3-family model for 200 steps on CPU with
over-decomposition 4 + checkpoint/resume — every substrate the production
path uses (just smaller). Pass ``--full-100m`` for a ~100M model (slower).

    PYTHONPATH=src python examples/train_lm.py
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import GLOBAL_ATTN, LOCAL_ATTN, ModelConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import build_smoke
from repro.train import (AdamWConfig, TrainConfig, init_train_state,
                         make_train_step)

SMALL = ModelConfig(
    name="gemma3-15m", family="dense", n_layers=6, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=1024, vocab=8192, head_dim=64,
    layer_pattern=(LOCAL_ATTN,) * 5 + (GLOBAL_ATTN,), window=64)

FULL_100M = dataclasses.replace(
    SMALL, name="gemma3-100m", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab=32768)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = FULL_100M if args.full_100m else SMALL
    model = build_smoke(cfg)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    tcfg = TrainConfig(
        opt=AdamWConfig(lr_peak=3e-3, warmup_steps=20,
                        total_steps=args.steps),
        over_decompose=4)           # the paper's over-decomposition
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=128,
                                  global_batch=8))
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0))
    ck = Checkpointer(args.ckpt, keep=2)

    import time
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step(state, batch)
        if (i + 1) % 20 == 0:
            dt = (time.time() - t0) / 20
            print(f"step {i+1:4d} loss={float(metrics['loss']):.4f} "
                  f"({dt*1e3:.0f} ms/step)", flush=True)
            t0 = time.time()
        if (i + 1) % 100 == 0:
            ck.save(i + 1, state)
    ck.save(args.steps, state, block=True)
    print("final loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
