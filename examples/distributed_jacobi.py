"""Distributed Jacobi3D with over-decomposition (paper §4.3–4.4).

Runs the proxy app three ways and cross-checks them:
  1. single-array reference
  2. PREMA-tasked over-decomposed version (runtime infers the halo/update
     dependency pipeline — Fig. 14)
  3. SPMD production version (shard_map + ppermute halo exchange) in both
     the bulk-synchronous (MPI-like) and overlapped schedules

    PYTHONPATH=src python examples/distributed_jacobi.py
"""
import time

import numpy as np

from repro.apps.jacobi3d import run_reference, run_spmd, run_tasked
from repro.core import Runtime, RuntimeConfig
from repro.launch.mesh import make_smoke_mesh


def main():
    rng = np.random.default_rng(0)
    u0 = rng.random((32, 32, 32)).astype(np.float32)
    iters = 5

    t0 = time.time()
    want = run_reference(u0, iters)
    print(f"reference:            {time.time()-t0:6.2f}s")

    for od in (1, 2, 4):
        with Runtime(RuntimeConfig()) as rt:
            t0 = time.time()
            got = run_tasked(u0, iters, rt, over_decomposition=od)
            dt = time.time() - t0
        err = float(np.abs(got - want).max())
        print(f"tasked  (OD={od}):      {dt:6.2f}s  max err {err:.2e}")

    mesh = make_smoke_mesh(1, 1)
    for bulk in (True, False):
        t0 = time.time()
        got = run_spmd(u0, iters, mesh, axis="data", bulk_sync=bulk)
        dt = time.time() - t0
        err = float(np.abs(got - want).max())
        mode = "bulk-sync (MPI-like)" if bulk else "overlapped         "
        print(f"spmd {mode}: {dt:6.2f}s  max err {err:.2e}")


if __name__ == "__main__":
    main()
