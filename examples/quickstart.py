"""Quickstart — the heterogeneous tasking framework in 40 lines.

The paper's Fig. 3 DGEMM example in this framework: define kernels once
(JAX = the portable kernel dialect), declare data as hetero_objects, declare
tasks with access modes, and let the runtime infer dependencies, place work,
and move data.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import HeteroTask, Runtime, RuntimeConfig


def dgemm(a, b, c):
    """Device-independent kernel: lowers to CPU/GPU/TPU via XLA."""
    return (a @ b).astype(c.dtype)


def main():
    with Runtime(RuntimeConfig()) as rt:
        n = 512
        A = rt.hetero_object(np.random.rand(n, n).astype(np.float32))
        B = rt.hetero_object(np.random.rand(n, n).astype(np.float32))
        C = rt.hetero_object(shape=(n, n), dtype=np.float32)
        D = rt.hetero_object(shape=(n, n), dtype=np.float32)

        # builder API, like the paper's listing
        t1 = HeteroTask("dgemm1")
        t1.arg(A).read()
        t1.arg(B).read()
        t1.arg(C).write()
        t1.set_threads((32, 32, 1), (32, 32, 1))   # advisory under XLA
        t1.device(rt.devices[0].info.device_type)  # a device TYPE, not an id
        rt.submit(t1, dgemm)

        # second DGEMM depends on the first through C — inferred implicitly
        t2 = HeteroTask("dgemm2")
        t2.arg(C).read()
        t2.arg(B).read()
        t2.arg(D).write()
        rt.submit(t2, dgemm)

        rt.barrier()
        want = (np.asarray(A.get()) @ np.asarray(B.get())) @ np.asarray(
            B.get())
        err = float(np.max(np.abs(D.get() - want)))
        print(f"double DGEMM max err = {err:.2e}")
        print("runtime stats:", rt.stats())


if __name__ == "__main__":
    main()
