"""Batched serving example: prefill a batch of prompts, decode with a KV
cache, verify against the full forward pass.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import Engine
from repro.models import build_smoke
from repro.models.layers import unbox


def main():
    cfg = get_smoke_config("yi_9b")
    model = build_smoke(cfg)
    params, _ = unbox(model.init(jax.random.PRNGKey(0)))
    batch, prompt_len, gen = 4, 32, 24

    eng = Engine(model, params, batch, prompt_len + gen)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = eng.generate(prompts, gen)
    dt = time.time() - t0
    print(f"generated {out.shape[1]} tokens × {batch} requests "
          f"in {dt:.2f}s ({batch * gen / dt:.1f} tok/s)")

    # verify: greedy decode must match argmax over the full forward pass
    full = jnp.concatenate([prompts, out[:, :-1]], axis=1)
    hidden, _, _ = model.apply(params, {"tokens": full}, mode="train")
    logits = model.unembed(params, hidden)
    want = jnp.argmax(logits[:, prompt_len - 1:], axis=-1)
    match = float(jnp.mean((want == out).astype(jnp.float32)))
    print(f"greedy-vs-full-forward agreement: {match*100:.1f}%")
    assert match > 0.99, "decode path diverges from the full forward pass"


if __name__ == "__main__":
    main()
