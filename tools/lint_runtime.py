#!/usr/bin/env python
"""Runtime-discipline lint for the repro runtime (AST-based, stdlib-only).

Scope: ``src/repro/core`` and ``src/repro/distributed``. Four rules:

R1  wall-clock ban — no ``time.time()`` / ``time.monotonic()`` calls.
    Deadline arithmetic must go through ``repro.core.clock`` so tests can
    inject a fake clock and NTP steps cannot corrupt timeouts.
    ``time.perf_counter()`` (interval measurement) stays legal.
    ``src/repro/core/clock.py`` is the one exempt module.

R2  raw-lock ban — no ``threading.Lock() / RLock() / Condition()``
    construction. Locks must come from ``sanitizer.make_lock`` /
    ``make_rlock`` / ``make_condition`` so the concurrency sanitizer can
    wrap them for lock-order tracking. ``sanitizer.py`` itself is exempt
    (it builds the primitives it wraps).

R3  stats-key registration — every constant key written through a
    ``*stats[...]`` subscript must appear in some registered surface:
    a dict literal initialising a ``*stats`` attribute, a
    ``stats()`` / ``state_gauges()`` / ``stats_snapshot()`` method, or a
    ``*stats.setdefault(...)`` call. Unregistered keys are counters that
    exist only while incremented — invisible to reports and leak checks.
    The registry is global across the scope (writers and surfaces may
    live in different modules).

R4  lane-blocking ban — functions submitted to progress-engine lanes
    (``<lane>.submit(fn)``, ``engine.submit(kind, key, fn)``,
    ``net.submit(kind, link, fn)``) must not block: no ``.result()``,
    no ``.wait(...)``, no ``fut.get(...)``, no ``time.sleep``. Resolved
    one call level deep within the same module. A genuine, bounded wait
    on an allowed lane is annotated ``# lint: allow-blocking`` on the
    offending line or the enclosing ``def`` line — the annotation is a
    reviewed contract, not a free pass.

Findings not expressible as code changes go in
``tools/lint_runtime_allowlist.txt`` (one ``RULE path::qualname`` per
line). Stale entries are themselves errors, so the allowlist can only
shrink.

Exit status: 0 clean, 1 findings, 2 usage/internal error.
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

REPO = Path(__file__).resolve().parent.parent
SCOPE = ("src/repro/core", "src/repro/distributed")
R1_EXEMPT = {"src/repro/core/clock.py"}
R2_EXEMPT = {"src/repro/core/sanitizer.py"}
ALLOWLIST = REPO / "tools" / "lint_runtime_allowlist.txt"

WALLCLOCK = {"time", "monotonic"}          # attrs of the time module
LOCK_CTORS = {"Lock", "RLock", "Condition"}
STATS_SURFACES = {"stats", "state_gauges", "stats_snapshot"}
ESCAPE = "lint: allow-blocking"


class Finding:
    def __init__(self, rule: str, path: str, line: int, qual: str,
                 msg: str) -> None:
        self.rule = rule
        self.path = path
        self.line = line
        self.qual = qual
        self.msg = msg

    @property
    def key(self) -> str:
        return f"{self.rule} {self.path}::{self.qual}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.qual}] {self.msg}"


def _is_stats_name(node: ast.AST) -> bool:
    """True for expressions naming a stats container: ``self.stats``,
    ``rank._stats``, ``mon.ctrl_stats``, bare ``stats``…"""
    if isinstance(node, ast.Attribute):
        return node.attr.endswith("stats") or node.attr.endswith("_stats")
    if isinstance(node, ast.Name):
        return node.id.endswith("stats")
    return False


def _const_key(sub: ast.Subscript) -> Optional[str]:
    sl = sub.slice
    if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
        return sl.value
    return None


class _QualTracker(ast.NodeVisitor):
    """Base visitor that maintains the enclosing qualname stack."""

    def __init__(self) -> None:
        self.stack: List[str] = []

    @property
    def qual(self) -> str:
        return ".".join(self.stack) if self.stack else "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


class _Registry(_QualTracker):
    """Pass 1 of R3: collect every registered stats key in a module."""

    def __init__(self) -> None:
        super().__init__()
        self.keys: Set[str] = set()

    def _dict_keys(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Dict):
                for k in sub.keys:
                    if isinstance(k, ast.Constant) and isinstance(k.value, str):
                        self.keys.add(k.value)

    def visit_Assign(self, node: ast.Assign) -> None:
        if any(_is_stats_name(t) for t in node.targets):
            self._dict_keys(node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None and _is_stats_name(node.target):
            self._dict_keys(node.value)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if node.name in STATS_SURFACES:
            # every string constant inside a stats surface registers a key
            for sub in ast.walk(node):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    self.keys.add(sub.value)
        super().visit_FunctionDef(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr in ("setdefault", "update")
                and _is_stats_name(f.value)):
            for a in node.args:
                if isinstance(a, ast.Constant) and isinstance(a.value, str):
                    self.keys.add(a.value)
                self._dict_keys(a)
        self.generic_visit(node)


class _Checker(_QualTracker):
    """Pass 2: R1, R2, R3-writes, and lane-submission discovery for R4."""

    def __init__(self, path: str, registry: Set[str],
                 lines: List[str]) -> None:
        super().__init__()
        self.path = path
        self.registry = registry
        self.lines = lines
        self.findings: List[Finding] = []
        self.defs: Dict[str, ast.FunctionDef] = {}
        self.submitted: Set[str] = set()         # function names given to lanes
        self.submitted_lambdas: List[Tuple[ast.Lambda, str]] = []

    def _flag(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(
            Finding(rule, self.path, node.lineno, self.qual, msg))

    # -- R1 / R2 / submit discovery ------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
            if (f.value.id == "time" and f.attr in WALLCLOCK
                    and self.path not in R1_EXEMPT):
                self._flag("R1", node,
                           f"wall-clock time.{f.attr}() — use "
                           "repro.core.clock (perf_counter ok)")
            if (f.value.id == "threading" and f.attr in LOCK_CTORS
                    and self.path not in R2_EXEMPT):
                self._flag("R2", node,
                           f"raw threading.{f.attr}() — use "
                           f"sanitizer.make_{f.attr.lower()}")
        if isinstance(f, ast.Attribute) and f.attr == "submit":
            for a in node.args:
                if isinstance(a, ast.Name):
                    self.submitted.add(a.id)
                elif isinstance(a, ast.Attribute):
                    self.submitted.add(a.attr)
                elif isinstance(a, ast.Lambda):
                    self.submitted_lambdas.append((a, self.qual))
        self.generic_visit(node)

    # -- R3 writes -----------------------------------------------------
    def _check_store(self, target: ast.AST) -> None:
        if isinstance(target, ast.Subscript) and _is_stats_name(target.value):
            key = _const_key(target)
            if key is not None and key not in self.registry:
                self._flag("R3", target,
                           f"stats key {key!r} written but never registered "
                           "in a stats()/state_gauges() surface or init dict")

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._check_store(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_store(node.target)
        self.generic_visit(node)

    # -- collect defs for R4 resolution --------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.defs.setdefault(node.name, node)
        super().visit_FunctionDef(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def _escaped(lines: List[str], *linenos: int) -> bool:
    return any(0 < n <= len(lines) and ESCAPE in lines[n - 1]
               for n in linenos)


def _blocking_calls(fn: ast.AST) -> List[Tuple[ast.Call, str]]:
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not isinstance(f, ast.Attribute):
            continue
        if f.attr == "result":
            out.append((node, ".result()"))
        elif f.attr == "wait":
            out.append((node, ".wait()"))
        elif f.attr == "get":
            recv = f.value
            nm = (recv.id if isinstance(recv, ast.Name)
                  else recv.attr if isinstance(recv, ast.Attribute) else "")
            if "fut" in nm:
                out.append((node, f"{nm}.get()"))
        elif (f.attr == "sleep" and isinstance(f.value, ast.Name)
              and f.value.id == "time"):
            out.append((node, "time.sleep()"))
    return out


def _callees(fn: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name):
                names.add(f.id)
            elif isinstance(f, ast.Attribute):
                names.add(f.attr)
    return names


def check_r4(chk: _Checker) -> List[Finding]:
    findings: List[Finding] = []

    def scan(fn: ast.AST, qual: str, deflineno: int, via: str) -> None:
        for call, what in _blocking_calls(fn):
            # the annotation may sit on the call line, the line above it,
            # or the enclosing def line
            if _escaped(chk.lines, call.lineno, call.lineno - 1, deflineno):
                continue
            findings.append(Finding(
                "R4", chk.path, call.lineno, qual,
                f"blocking {what} inside lane-submitted code ({via}); "
                "restructure as continuation or annotate "
                "'# lint: allow-blocking'"))

    for name in sorted(chk.submitted):
        fn = chk.defs.get(name)
        if fn is None:
            continue
        scan(fn, name, fn.lineno, f"submitted fn {name}")
        for callee in sorted(_callees(fn)):        # one level deep
            sub = chk.defs.get(callee)
            if sub is not None and callee != name:
                scan(sub, callee, sub.lineno,
                     f"{callee} called from lane-submitted {name}")
    for lam, qual in chk.submitted_lambdas:
        scan(lam, qual, lam.lineno, "submitted lambda")
        # a submitted lambda is a partial-application trampoline: the
        # named functions it calls get the full one-level treatment
        for callee in sorted(_callees(lam)):
            sub = chk.defs.get(callee)
            if sub is None:
                continue
            scan(sub, callee, sub.lineno,
                 f"{callee} called from lambda submitted in {qual}")
            for deeper in sorted(_callees(sub)):
                sub2 = chk.defs.get(deeper)
                if sub2 is not None and deeper != callee:
                    scan(sub2, deeper, sub2.lineno,
                         f"{deeper} called from lane-submitted {callee}")
    return findings


def load_allowlist() -> Set[str]:
    if not ALLOWLIST.exists():
        return set()
    out = set()
    for raw in ALLOWLIST.read_text().splitlines():
        line = raw.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def run(paths: Optional[List[str]] = None) -> int:
    files: List[Path] = []
    for scope in SCOPE:
        files.extend(sorted((REPO / scope).glob("*.py")))
    if paths:
        want = {Path(p).resolve() for p in paths}
        files = [f for f in files if f.resolve() in want]

    parsed = []
    registry: Set[str] = set()
    for f in files:
        rel = str(f.relative_to(REPO))
        src = f.read_text()
        try:
            tree = ast.parse(src, filename=rel)
        except SyntaxError as e:
            print(f"{rel}: syntax error: {e}", file=sys.stderr)
            return 2
        reg = _Registry()
        reg.visit(tree)
        registry |= reg.keys
        parsed.append((rel, tree, src.splitlines()))

    findings: List[Finding] = []
    for rel, tree, lines in parsed:
        chk = _Checker(rel, registry, lines)
        chk.visit(tree)
        findings.extend(chk.findings)
        findings.extend(check_r4(chk))

    allow = load_allowlist()
    used: Set[str] = set()
    shown = []
    for fd in findings:
        if fd.key in allow:
            used.add(fd.key)
            continue
        shown.append(fd)
    for fd in shown:
        print(fd)
    stale = allow - used
    for entry in sorted(stale):
        print(f"allowlist: stale entry (no longer matches any finding, "
              f"delete it): {entry}")
    if shown or stale:
        print(f"\nlint_runtime: {len(shown)} finding(s), "
              f"{len(stale)} stale allowlist entr(y/ies)")
        return 1
    print(f"lint_runtime: clean ({len(files)} files, "
          f"{len(registry)} registered stats keys, "
          f"{len(allow)} allowlisted)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="restrict to these files (default: full scope)")
    args = ap.parse_args()
    return run(args.paths or None)


if __name__ == "__main__":
    sys.exit(main())
